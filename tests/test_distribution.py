"""Sharding rules, checkpointing, fault tolerance, compression, mining units."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.distributed.sharding import MeshRules
from repro.launch.mesh import make_mesh
from repro.optim import AdamW, compression
from jax.sharding import PartitionSpec as P


def test_sharding_divisibility_fallback():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh)
    # trivial mesh: everything resolves (axis size 1 divides all)
    assert rules.spec_for(("embed", "ff"), (64, 256)) == P(None, "model")
    # simulated 16-wide model axis via custom rules table
    rules16 = MeshRules(mesh)
    rules16.mesh = mesh  # spec_for only uses shape dict below

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    r = MeshRules.__new__(MeshRules)
    r.mesh = FakeMesh()
    r.rules = dict(MeshRules(mesh).rules)
    # 10 heads do not divide 16 -> replicate; 7680 ff does -> shard
    assert r.spec_for(("heads",), (10,)) == P()
    assert r.spec_for(("ff",), (7680,)) == P("model")
    # batch spreads over (pod, data) when both divide
    r.mesh.axis_names = ("pod", "data", "model")
    r.mesh.shape = {"pod": 2, "data": 16, "model": 16}
    assert r.spec_for(("batch",), (256,)) == P(("pod", "data"))
    # batch=1 (long_500k) -> replicated, never crashes
    assert r.spec_for(("batch",), (1,)) == P()


def test_param_spec_tree_alignment():
    """Every arch's spec tree zips leaf-for-leaf with its param tree."""
    from repro.configs import REGISTRY, reduced
    from repro.models import Model
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh)
    for name, cfg in sorted(REGISTRY.items()):
        m = Model(reduced(cfg))
        shapes = jax.eval_shape(lambda m=m: m.init(jax.random.PRNGKey(0)))
        rules.tree_shardings(m.param_specs(), shapes)   # raises on mismatch
        cache_shapes = jax.eval_shape(lambda m=m: m.init_cache(2, 16))
        rules.tree_shardings(m.cache_specs(), cache_shapes)


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)]}
    ck.save(10, tree, blocking=True)
    ck.save(20, tree, blocking=True)
    ck.save(30, tree, blocking=True)
    assert ck.list_steps() == [20, 30]  # keep=2 gc'd step 10
    out = ck.restore(tree, step=20)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"][0].dtype == jnp.bfloat16


def test_resilient_loop_resume(tmp_path):
    from repro.distributed.fault_tolerance import resilient_train_loop
    ck = Checkpointer(str(tmp_path), keep=3)

    def step_fn(state, batch):
        return state + batch, {"loss": float(state)}

    def batches():
        while True:
            yield jnp.float32(1.0)

    class Boom(RuntimeError):
        pass

    def injector(step):
        if step == 7 and not os.environ.get("_RESUMED"):
            raise Boom()

    with pytest.raises(Boom):
        resilient_train_loop(
            step_fn=step_fn, init_state=jnp.float32(0.0), batch_iter=batches(),
            checkpointer=ck, n_steps=12, ckpt_every=3, fail_injector=injector)
    assert ck.latest_step() == 6
    os.environ["_RESUMED"] = "1"
    try:
        state, start, hist = resilient_train_loop(
            step_fn=step_fn, init_state=jnp.float32(0.0), batch_iter=batches(),
            checkpointer=ck, n_steps=12, ckpt_every=3, fail_injector=injector)
    finally:
        del os.environ["_RESUMED"]
    assert start == 6
    assert float(state) == 12.0  # exactly-once step semantics across restart


def test_straggler_monitor():
    mon = StragglerMonitor(window=100.0, repeat=3, min_count=2)
    rng = np.random.default_rng(0)
    wall = 0.0
    for step in range(60):
        durs = {f"h{i}": 1.0 + rng.normal(0, 0.01) for i in range(4)}
        if step > 10:
            durs["h2"] = 2.5
        wall += 2.5
        mon.record_step(durs, wall)
    assert mon.flagged() == ["h2"]


def test_gradient_compression_error_feedback():
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                              jnp.float32)}
    err = compression.init_error_state(grads)
    key = jax.random.PRNGKey(0)
    # accumulated dequantized grads converge to the true sum (error feedback)
    total_q = jnp.zeros((256,))
    for i in range(32):
        deq, err = compression.compress_grads(grads, err, jax.random.fold_in(key, i))
        total_q = total_q + deq["w"]
    true_total = grads["w"] * 32
    rel = float(jnp.linalg.norm(total_q - true_total)
                / jnp.linalg.norm(true_total))
    assert rel < 0.02, rel


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.3


def test_mining_end_to_end():
    from repro.core import MinerConfig, mine
    from repro.data.spikes import NetworkConfig, embedded_episodes, simulate
    net = NetworkConfig(n_neurons=16, episode_len=4, n_embedded=2,
                        base_rate=5.0, trigger_hz=8.0)
    stream = simulate(net, 8.0)
    truth = embedded_episodes(net)
    cfg = MinerConfig(t_low=0.0, t_high=2 * net.delay_high, threshold=12,
                      level_thresholds={2: 18}, max_level=3,
                      max_candidates=512)
    res = mine(stream, cfg)
    lvl3 = {e.symbols for e in res[3].episodes}
    assert any(t.symbols[:3] in lvl3 for t in truth)


def test_elastic_remesh_shrinks():
    from repro.distributed.fault_tolerance import elastic_remesh
    mesh, rules = elastic_remesh((8, 1), ("data", "model"))
    # only 1 CPU device available -> data axis shrinks to fit
    assert mesh.devices.size <= jax.device_count()
