"""Boundary-convention pin: the half-open ``(lo, hi]`` window and the strict
greedy tie, at EXACT boundary timestamps, across every engine and both
schedulers (DESIGN.md §3).

The streaming miner stitches tail occurrences onto cached greedy state, the
sharded miner stitches across shard boundaries — both are exact only if
every engine agrees on what happens when ``t_next - t_prev`` lands exactly
on ``hi`` (inside) or exactly on ``lo`` (outside), and when an occurrence
starts exactly at the previous occurrence's end (not taken: ``s > prev_e``
is strict). These tests pin those conventions with hand-computable streams
on an exactly-representable 0.25 grid, checked against the FSM oracle, so
a future engine (or refactor) that drifts fails loudly here instead of
silently disagreeing at a stitch boundary.
"""
import numpy as np
import pytest

from repro.core import (Episode, EventStream, count_fsm_numpy,
                        count_nonoverlapped, serial)

ENGINES = ("dense", "dense_pallas", "dense_pallas_fused", "count_scan_write",
           "atomic_sort", "flags")
SCHEDULERS = (False, True)   # greedy_scan, greedy_parallel


def _count(stream, ep, engine, parallel):
    res = count_nonoverlapped(
        stream, ep, engine=engine, parallel_schedule=parallel,
        cap_occ=4 * max(1, stream.n_events), max_window=64)
    assert not bool(res.overflow)
    return int(res.count)


def _check_all(stream, ep, expected):
    oracle = count_fsm_numpy(stream.types, stream.times, ep)
    assert oracle == expected, f"oracle disagrees: {oracle} != {expected}"
    for engine in ENGINES:
        for parallel in SCHEDULERS:
            got = _count(stream, ep, engine, parallel)
            assert got == expected, (
                f"{engine}/{'parallel' if parallel else 'scan'}: "
                f"{got} != {expected} for {ep}")


@pytest.mark.parametrize("gap,expected", [
    (1.0, 1),    # t_next - t_prev == hi exactly: INSIDE the half-open window
    (0.25, 0),   # == lo exactly: OUTSIDE (strict lower bound)
    (0.5, 1),    # interior sanity
    (1.25, 0),   # past hi
    (0.0, 0),    # simultaneous events: 0 <= lo is outside for any lo >= 0
])
def test_exact_boundary_gap_two_symbols(gap, expected):
    stream = EventStream(np.array([0, 1], np.int32),
                         np.array([1.0, 1.0 + gap], np.float32), 2)
    _check_all(stream, serial([0, 1], 0.25, 1.0), expected)


def test_exact_boundary_gap_zero_low():
    """lo == 0: a zero gap (duplicate timestamp) is still strictly outside."""
    stream = EventStream(np.array([0, 1], np.int32),
                         np.array([2.0, 2.0], np.float32), 2)
    _check_all(stream, serial([0, 1], 0.0, 1.0), 0)
    stream2 = EventStream(np.array([0, 1], np.int32),
                          np.array([2.0, 3.0], np.float32), 2)
    _check_all(stream2, serial([0, 1], 0.0, 1.0), 1)


def test_exact_boundaries_per_gap_windows():
    """A 3-symbol episode with per-gap windows, each gap at its own exact
    boundary: first at hi_1 (inside), second at lo_2 (outside) and just
    above (inside)."""
    ep = Episode((0, 1, 2), (0.25, 0.5), (1.0, 2.0))
    # gap1 == hi1 == 1.0 (in), gap2 == lo2 == 0.5 (out) -> no occurrence
    s_out = EventStream(np.array([0, 1, 2], np.int32),
                        np.array([0.0, 1.0, 1.5], np.float32), 3)
    _check_all(s_out, ep, 0)
    # gap2 == 0.75 (in) -> one occurrence
    s_in = EventStream(np.array([0, 1, 2], np.int32),
                       np.array([0.0, 1.0, 1.75], np.float32), 3)
    _check_all(s_in, ep, 1)
    # gap2 == hi2 == 2.0 exactly (in)
    s_hi = EventStream(np.array([0, 1, 2], np.int32),
                       np.array([0.0, 1.0, 3.0], np.float32), 3)
    _check_all(s_hi, ep, 1)


def test_greedy_tie_start_equals_prev_end():
    """Two chained occurrences sharing one boundary timestamp: the second
    STARTS exactly at the first's END, so the strict scheduler takes one."""
    # A@0 B@1 (occurrence [0,1]) then A@1 B@2 (occurrence [1,2]):
    # 1 is not > 1, so the second cannot follow the first -> count 1
    stream = EventStream(np.array([0, 1, 0, 1], np.int32),
                         np.array([0.0, 1.0, 1.0, 2.0], np.float32), 2)
    _check_all(stream, serial([0, 1], 0.25, 1.0), 1)
    # pushing the second pair 0.25 later separates them -> count 2
    stream2 = EventStream(np.array([0, 1, 0, 1], np.int32),
                          np.array([0.0, 1.0, 1.25, 2.25], np.float32), 2)
    _check_all(stream2, serial([0, 1], 0.25, 1.0), 2)


@pytest.mark.parametrize("seed", range(6))
def test_boundary_grid_differential(seed):
    """Streams whose every gap is drawn from {0, lo, mid, hi, hi+step} on an
    exact 0.25 grid — every inter-event distance in the stream sits on or
    next to a window boundary — differentially against the FSM oracle."""
    rng = np.random.default_rng(seed)
    lo, hi = 0.25, 1.0
    n, n_types = 24, 3
    gaps = rng.choice(np.array([0.0, lo, 0.5, hi, hi + 0.25], np.float32), n)
    times = np.cumsum(gaps).astype(np.float32)
    types = rng.integers(0, n_types, n).astype(np.int32)
    stream = EventStream(types, times, n_types)
    episodes = [serial([0, 1], lo, hi), serial([1, 0, 2], lo, hi),
                serial([0, 0], lo, hi), serial([2, 1, 0], 0.0, hi)]
    for ep in episodes:
        expected = count_fsm_numpy(types, times, ep)
        for engine in ENGINES:
            for parallel in SCHEDULERS:
                got = _count(stream, ep, engine, parallel)
                assert got == expected, (seed, str(ep), engine, parallel)
