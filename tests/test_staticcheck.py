"""Tests for repro.analysis.staticcheck (DESIGN.md §13).

Every REPRO### rule gets a flagging fixture AND a non-flagging fixture;
the jaxpr layer is verified against deliberately-broken plan builders
(injected host callback, non-class-rounded shape, t_min double-apply —
the exact PR 5/6/7 regressions); suppression comments and the baseline
are honored; and the tree itself must be clean.
"""
import dataclasses
import textwrap

import jax
import pytest

from repro.analysis import staticcheck
from repro.analysis.staticcheck import astlint, jaxpr_checks
from repro.analysis.staticcheck.findings import (
    Baseline, BaselineEntry, Finding, parse_suppressions)
from repro.core import plan as plan_mod
from repro.core import tracking


def lint(code: str, path: str = "src/repro/core/x.py"):
    return astlint.lint_source(path, textwrap.dedent(code))


def codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# REPRO001 — falsy-or capacity defaults (the PR 5 cap=0 bug class)
# ---------------------------------------------------------------------------


class TestRepro001:
    def test_flags_falsy_or_default(self):
        # the exact PR 5 bug shape: cap=0 is a VALID width that `or`
        # silently replaces with the default
        fs = lint("""
            def resolve_cap(cap, n_events):
                return cap or n_events
        """)
        assert codes(fs) == ["REPRO001"]

    def test_flags_attribute_capacity(self):
        fs = lint("""
            def f(cfg, stream):
                width = cfg.cap_occ or 32
                return width
        """)
        assert codes(fs) == ["REPRO001"]

    def test_is_none_default_clean(self):
        fs = lint("""
            def resolve_cap(cap, n_events):
                return cap if cap is not None else n_events
        """)
        assert fs == []

    def test_truthiness_test_position_clean(self):
        # `if cap or tail_cap:` is a genuine truthiness test, not a default
        fs = lint("""
            def f(cap, tail_cap):
                if cap or tail_cap:
                    return 1
                while cap or tail_cap:
                    break
                assert cap or tail_cap
                return 0
        """)
        assert fs == []

    def test_non_capacity_names_clean(self):
        fs = lint("""
            def f(name, fallback):
                return name or fallback
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# REPRO002 — unthreaded interpret/tile knobs
# ---------------------------------------------------------------------------


class TestRepro002:
    def test_flags_swallowed_knob(self):
        fs = lint("""
            def track(x, interpret=False, block_next=256):
                return run(x, block_next=block_next)
        """)
        assert codes(fs) == ["REPRO002"]
        assert "interpret" in fs[0].message

    def test_threaded_knob_clean(self):
        fs = lint("""
            def track(x, interpret=False, block_next=256):
                return run(x, block_next=block_next, interpret=interpret)
        """)
        assert fs == []

    def test_protocol_stub_clean(self):
        fs = lint("""
            def track(x, interpret=False):
                ...

            def track2(x, chunk=8):
                raise NotImplementedError
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# REPRO003 — jit/pallas_call outside the dispatch spine
# ---------------------------------------------------------------------------


class TestRepro003:
    def test_flags_direct_jit_call(self):
        fs = lint("""
            import jax
            def f(fn):
                return jax.jit(fn)
        """)
        assert codes(fs) == ["REPRO003"]

    def test_flags_jit_decorator(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                return x
        """)
        assert codes(fs) == ["REPRO003"]

    def test_flags_partial_jit(self):
        fs = lint("""
            import functools, jax
            @functools.partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                return x
        """)
        assert codes(fs) == ["REPRO003"]

    def test_flags_pallas_call(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            def f(kernel, spec):
                return pl.pallas_call(kernel, out_shape=spec)
        """)
        assert codes(fs) == ["REPRO003"]

    def test_spine_paths_allowed(self):
        code = """
            import jax
            def f(fn):
                return jax.jit(fn)
        """
        assert lint(code, path="src/repro/core/plan.py") == []
        assert lint(code, path="src/repro/kernels/episode_track.py") == []

    def test_dispatch_clean(self):
        fs = lint("""
            from repro.core import plan
            def f(p, *args):
                return plan.dispatch(p, *args)
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# REPRO004 — syncs inside loop bodies
# ---------------------------------------------------------------------------


class TestRepro004:
    def test_flags_device_get_in_loop(self):
        fs = lint("""
            import jax
            def mine(levels):
                for level in levels:
                    counts = jax.device_get(level)
        """)
        assert codes(fs) == ["REPRO004"]

    def test_flags_block_until_ready_in_while(self):
        fs = lint("""
            def wait(x):
                while True:
                    x.block_until_ready()
        """)
        assert codes(fs) == ["REPRO004"]

    def test_sync_outside_loop_clean(self):
        fs = lint("""
            import jax
            def fetch(dev):
                return jax.device_get(dev)
        """)
        assert fs == []

    def test_closure_resets_loop_depth(self):
        # a helper *defined* inside a loop is not itself a loop-body sync
        fs = lint("""
            import jax
            def f(items):
                for it in items:
                    def fetch(x):
                        return jax.device_get(x)
                return fetch
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# REPRO005 — unregistered registry candidates
# ---------------------------------------------------------------------------


class TestRepro005:
    def test_flags_unregistered_builder(self):
        fs = lint("""
            from repro.core import plan as plan_mod
            def _build_good(p):
                return p
            def _specs_good(p):
                return ()
            def _build_orphan(p):
                return p
            plan_mod.register_fn("good", _build_good, _specs_good)
        """)
        assert codes(fs) == ["REPRO005"]
        assert "_build_orphan" in fs[0].message

    def test_flags_unregistered_engine(self):
        fs = lint("""
            from repro.core.tracking import register_engine
            class GoodEngine:
                name = "good"
            class OrphanEngine:
                name = "orphan"
            register_engine(GoodEngine())
        """)
        assert codes(fs) == ["REPRO005"]
        assert "OrphanEngine" in fs[0].message

    def test_protocol_class_clean(self):
        fs = lint("""
            from typing import Protocol
            from repro.core.tracking import register_engine
            class TrackingEngine(Protocol):
                name: str
            class RealEngine:
                name = "real"
            register_engine(RealEngine())
        """)
        assert fs == []

    def test_module_without_registration_clean(self):
        # helper names are only registry candidates in registering modules
        fs = lint("""
            def _build_table(rows):
                return rows
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# REPRO006 / REPRO007 — mechanical hygiene
# ---------------------------------------------------------------------------


class TestMechanicalRules:
    def test_flags_trailing_whitespace(self):
        fs = astlint.lint_text("x.py", "a = 1 \nb = 2\n")
        assert codes(fs) == ["REPRO006"]
        assert fs[0].line == 1

    def test_flags_tab(self):
        fs = astlint.lint_text("x.py", "def f():\n\treturn 1\n")
        assert codes(fs) == ["REPRO007"]

    def test_clean_text(self):
        assert astlint.lint_text("x.py", "a = 1\nb = 2\n") == []

    def test_runs_on_non_python_files(self):
        fs = astlint.lint_text("config.yml", "key: value \n")
        assert codes(fs) == ["REPRO006"]


# ---------------------------------------------------------------------------
# suppression + baseline policy
# ---------------------------------------------------------------------------


class TestSuppression:
    def test_same_line_suppression(self):
        fs = lint("""
            import jax
            def f(fn):
                return jax.jit(fn)  # staticcheck: disable=REPRO003 -- why
        """)
        kept, muted = staticcheck.filter_findings(
            fs, sources={"src/repro/core/x.py": textwrap.dedent("""
            import jax
            def f(fn):
                return jax.jit(fn)  # staticcheck: disable=REPRO003 -- why
        """)}, baseline=Baseline([]))
        assert kept == []
        assert codes(muted) == ["REPRO003"]

    def test_standalone_comment_covers_next_code_line(self):
        src = textwrap.dedent("""
            import jax
            def f(fn):
                # staticcheck: disable=REPRO003 -- sanctioned bypass,
                # explained across two comment lines
                return jax.jit(fn)
        """)
        supp = parse_suppressions(src)
        fs = astlint.lint_source("x.py", src)
        assert all(f.line in supp and "REPRO003" in supp[f.line]
                   for f in fs if f.code == "REPRO003")

    def test_wrong_code_does_not_suppress(self):
        src = "a = 1  # staticcheck: disable=REPRO006\n"
        kept, muted = staticcheck.filter_findings(
            [Finding("x.py", 1, "REPRO007", "tab")],
            sources={"x.py": src}, baseline=Baseline([]))
        assert codes(kept) == ["REPRO007"]

    def test_baseline_exempts_by_path_and_code(self):
        bl = Baseline([BaselineEntry("src/repro/models/", ("REPRO003",),
                                     "seed scaffolding")])
        exempt = Finding("src/repro/models/model.py", 3, "REPRO003", "m")
        kept_f = Finding("src/repro/models/model.py", 3, "REPRO006", "m")
        kept, muted = staticcheck.filter_findings(
            [exempt, kept_f], sources={}, baseline=bl)
        assert codes(kept) == ["REPRO006"]
        assert codes(muted) == ["REPRO003"]

    def test_checked_in_baseline_never_mutes_mechanical_rules(self):
        # policy: REPRO006/REPRO007 run blocking on every file
        bl = staticcheck.load_baseline()
        for entry in bl.entries:
            assert "REPRO006" not in entry.codes
            assert "REPRO007" not in entry.codes
            assert "*" not in entry.codes


# ---------------------------------------------------------------------------
# Layer 1 — jaxpr checks against deliberately-broken builders
# ---------------------------------------------------------------------------


def _register_wrapped(name: str, wrap):
    """Register a counting fn that wraps count_indexed's traced body."""
    entry = plan_mod._fn_entry("count_indexed")

    def build(p):
        return wrap(entry.build(p))

    plan_mod.register_fn(name, build, entry.specs)
    return plan_mod.plan_for(name, level=3, n_types=8, cap=256, batch=8,
                             engine="dense", interpret=True)


@pytest.fixture
def scratch_registry():
    """Temporary fns registered by a test are dropped afterwards."""
    before = set(plan_mod._FNS)
    yield
    for name in set(plan_mod._FNS) - before:
        del plan_mod._FNS[name]


class TestJaxprLayer:
    def test_clean_plan_passes(self):
        p = plan_mod.plan_for("count_indexed", level=3, n_types=8, cap=256,
                              batch=8, engine="dense", interpret=True)
        assert jaxpr_checks.check_plan(p) == []

    def test_injected_host_callback_flags(self, scratch_registry):
        def wrap(fn):
            def bad(*args):
                jax.debug.callback(lambda: None)
                return fn(*args)
            return bad

        p = _register_wrapped("bad_cb", wrap)
        assert "REPRO101" in codes(jaxpr_checks.check_plan(p))

    def test_non_class_rounded_cap_flags(self):
        good = plan_mod.plan_for("count_indexed", level=3, n_types=8,
                                 cap=256, batch=8, engine="dense",
                                 interpret=True)
        bad = dataclasses.replace(good, cap=100)
        entry = plan_mod._fn_entry("count_indexed")
        fs = jaxpr_checks.check_rounding(bad, entry.specs(bad))
        assert "REPRO102" in codes(fs)

    def test_non_pow2_batch_flags(self):
        good = plan_mod.plan_for("count_indexed", level=3, n_types=8,
                                 cap=256, batch=8, engine="dense",
                                 interpret=True)
        bad = dataclasses.replace(good, batch=7)
        entry = plan_mod._fn_entry("count_indexed")
        fs = jaxpr_checks.check_rounding(bad, entry.specs(bad))
        assert "REPRO102" in codes(fs)

    def test_tmin_double_apply_flags(self, scratch_registry):
        # the PR 6 hazard: a builder applying the seed restriction itself
        # ON TOP of the t_min consume_seed_restriction performs
        def wrap(fn):
            def bad(table, *rest):
                table = tracking.restrict_seed_row(table[None], 0.0)[0]
                return fn(table, *rest)
            return bad

        p = _register_wrapped("bad_tmin", wrap)
        fs = jaxpr_checks.check_plan(p)
        assert "REPRO103" in codes(fs)

    def test_count_tail_applies_tmin_exactly_once(self):
        p = plan_mod.plan_for("count_tail", level=3, n_types=8, cap=256,
                              batch=8, tail_cap=64, engine="dense",
                              interpret=True)
        _closed, n = jaxpr_checks.trace_plan(p)
        assert n == 1
        assert jaxpr_checks.check_tmin(p, n) == []
        assert jaxpr_checks.check_tmin(p, 2) != []

    def test_tile_contract_flags_overbudget_vmem(self):
        fs = jaxpr_checks._tile_contract(
            "plan://synthetic", "count", 3, 1 << 16, 64, 256, 256, 0, 64)
        assert "REPRO104" in codes(fs)

    def test_tuned_table_clean(self):
        assert jaxpr_checks.check_tuned_table() == []

    def test_default_matrix_covers_every_fn_and_engine(self):
        plans = jaxpr_checks.default_matrix()
        fns = {p.fn for p in plans}
        engines = {p.engine for p in plans}
        assert fns == set(plan_mod._FNS)
        assert engines == set(tracking.engine_names())


# ---------------------------------------------------------------------------
# tree-is-clean smoke + runner plumbing
# ---------------------------------------------------------------------------


class TestTree:
    def test_lint_layer_tree_is_clean(self):
        report = staticcheck.run(jaxpr=False)
        assert report["ok"], report["text"]

    def test_default_matrix_tree_is_clean(self):
        report = staticcheck.run(matrix="default")
        assert report["ok"], report["text"]
        assert report["plans_checked"] > 0

    def test_report_json_roundtrip(self):
        import json
        report = staticcheck.run(jaxpr=False)
        blob = json.loads(staticcheck.report_json(report))
        assert blob["ok"] is True
        assert blob["files_checked"] == report["files_checked"]

    def test_changed_files_subset_of_tree(self):
        root = staticcheck.runner.repo_root()
        tree = set(staticcheck.discover_files(root))
        for rel in staticcheck.changed_files(root):
            assert rel in tree
