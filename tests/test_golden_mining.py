"""Golden-fixture regression: every miner recovers the checked-in answer.

tests/data/golden_stream.npz (scripts/make_golden_stream.py) is a small
simulated spike train with two planted cascades and the exact per-level
frequent sets — oracle-verified at generation time. `mine`, `mine_arrays`
(per engine), and `mine_sharded` (8 simulated devices, via the child
subprocess) must all reproduce it bit-for-bit.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import MinerConfig, mine, mine_arrays
from repro.core.events import EventStream

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "data" / "golden_stream.npz"


@pytest.fixture(scope="module")
def golden():
    data = np.load(GOLDEN)
    stream = EventStream(data["types"], data["times"], int(data["n_types"]))
    cfg_kw = dict(
        t_low=float(data["t_low"]), t_high=float(data["t_high"]),
        threshold=int(data["threshold"]), max_level=int(data["max_level"]),
        max_candidates=int(data["max_candidates"]))
    return data, stream, cfg_kw


def _assert_matches(res, data):
    levels = [int(l) for l in data["levels"]]
    assert sorted(res) == levels
    for lvl in levels:
        np.testing.assert_array_equal(
            res[lvl].symbols, data[f"level{lvl}_symbols"], err_msg=str(lvl))
        np.testing.assert_array_equal(
            res[lvl].counts, data[f"level{lvl}_counts"], err_msg=str(lvl))
        assert res[lvl].n_candidates == int(data[f"level{lvl}_n_candidates"])


@pytest.mark.parametrize("engine", ["dense", "dense_pallas",
                                    "dense_pallas_fused"])
def test_mine_arrays_recovers_golden(golden, engine):
    data, stream, cfg_kw = golden
    res = mine_arrays(stream, MinerConfig(**cfg_kw, engine=engine))
    _assert_matches(res, data)


def test_mine_episode_api_recovers_golden(golden):
    data, stream, cfg_kw = golden
    res = mine(stream, MinerConfig(**cfg_kw))
    levels = [int(l) for l in data["levels"]]
    assert sorted(res) == levels
    for lvl in levels:
        got_rows = np.asarray([e.symbols for e in res[lvl].episodes],
                              np.int32).reshape(-1, lvl)
        np.testing.assert_array_equal(got_rows, data[f"level{lvl}_symbols"])
        np.testing.assert_array_equal(res[lvl].counts,
                                      data[f"level{lvl}_counts"])


def test_planted_cascades_present(golden):
    """The fixture's deepest level contains a planted cascade prefix —
    the miner finds the structure the simulator embedded, not noise."""
    data, _, _ = golden
    deepest = int(max(data["levels"]))
    found = {tuple(int(x) for x in row)
             for row in data[f"level{deepest}_symbols"]}
    planted = [tuple(int(x) for x in row[:deepest])
               for row in data["planted_symbols"]]
    assert any(p in found for p in planted)


@pytest.mark.slow
def test_mine_sharded_recovers_golden_8dev():
    """mine_sharded on 8 simulated devices == the stored frequent sets
    (dense + fused engines; subprocess because jax locks device count)."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, str(REPO / "tests" / "sharded_mining_child.py"),
         "golden", "--golden-path", str(GOLDEN)],
        env=env, capture_output=True, text=True, timeout=900, cwd=str(REPO))
    assert r.returncode == 0 and "OK golden" in r.stdout, r.stdout + r.stderr
