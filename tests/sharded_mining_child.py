"""Subprocess child for the sharded differential suite.

jax locks the host-platform device count at first init, so everything that
needs 8 simulated devices runs here, spawned by tests/test_sharded_mining.py
(and tests/test_golden_mining.py) with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Prints ``OK ...`` on
success; any assertion failure propagates as a nonzero exit.

Modes:
  differential  hypothesis sweep: mine_sharded == mine_arrays (symbols,
                counts, candidate totals) for one engine across shard
                counts {1, 2, 8} on prime-length shards with duplicate
                timestamps
  straddle      same equality on streams whose occurrences straddle >= 3
                shards (multi-hop halo exactness)
  halo          fixed adversarial regressions: boundary-timestamp-tie
                ownership, the halo_end - boundary == span duplicate edge
                (flagged, never a silent undercount), per-episode flags in
                the batched path, >= 3-shard straddle
  golden        mine_sharded on the checked-in golden fixture equals the
                stored per-level frequent sets exactly
  corpus        stream-axis sharding: mine_corpus with a mesh (streams
                sharded over the devices, no halo) == the per-stream
                mine_arrays loop, ragged corpora with per-stream
                thresholds, alternating engines, shard counts {1, 2, 8}
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))   # for `import strategies`

import numpy as np
import jax.numpy as jnp


def _meshes():
    from repro.launch.mesh import make_mesh
    return {k: make_mesh((k,), ("data",)) for k in (1, 2, 8)}


def _engine_kwargs(engine, n_events):
    kw = dict(engine=engine)
    if engine == "count_scan_write":
        # generous static buffers so overflow stays rare on random streams
        kw.update(cap_occ=16 * max(n_events, 8), max_window=128)
    return kw


def _assert_levels_equal(base, got, ctx):
    assert base.keys() == got.keys(), (ctx, sorted(base), sorted(got))
    for lvl in base:
        np.testing.assert_array_equal(
            base[lvl].symbols, got[lvl].symbols, err_msg=f"{ctx} level {lvl}")
        np.testing.assert_array_equal(
            base[lvl].counts, got[lvl].counts, err_msg=f"{ctx} level {lvl}")
        assert base[lvl].n_candidates == got[lvl].n_candidates, (ctx, lvl)


def _foreach_seed(body, examples: int) -> None:
    """Run ``body(seed)`` on ``examples`` cases: hypothesis-driven (with
    shrinking) when the package is installed, a plain seeded loop when not
    — the same builders shape the cases either way."""
    import strategies as sts
    if sts.HAVE_HYPOTHESIS:
        from hypothesis import HealthCheck, given, settings

        @settings(max_examples=examples, deadline=None, database=None,
                  derandomize=True, suppress_health_check=list(HealthCheck))
        @given(seed=sts.seeds())
        def check(seed):
            body(seed)

        check()
    else:
        for seed in range(examples):
            body(seed)


def run_differential(engine: str, examples: int) -> None:
    import strategies as sts
    from repro.core import MinerConfig, mine_arrays

    meshes = _meshes()
    ran = {"n": 0}

    def body(seed):
        stream, n_shards, t_high, threshold = sts.make_sharded_case(seed)
        kw = dict(t_low=0.0, t_high=t_high, threshold=threshold, max_level=3,
                  **_engine_kwargs(engine, stream.n_events))
        base_err = got_err = None
        try:
            base = mine_arrays(stream, MinerConfig(**kw))
        except RuntimeError as e:
            base_err = str(e)
        try:
            got = mine_arrays(stream, MinerConfig(
                **kw, mesh=meshes[n_shards], n_shards=n_shards,
                halo=stream.n_events))   # full halo: exactness guaranteed
        except RuntimeError as e:
            got_err = str(e)
        if base_err or got_err:
            # capacity profiles differ across layouts, so a static-capacity
            # overflow may legitimately fire on one side only; what is
            # forbidden is a *silent* divergence, and mining raises on every
            # flag, so reaching here at all is the contract holding
            assert "overflow" in (base_err or got_err), (base_err, got_err)
            return
        _assert_levels_equal(base, got, (engine, n_shards, seed))
        ran["n"] += 1

    _foreach_seed(body, examples)
    print(f"OK differential engine={engine} examples={examples} "
          f"compared={ran['n']}")


def run_straddle(examples: int) -> None:
    import strategies as sts
    from repro.core import MinerConfig, mine_arrays

    mesh8 = _meshes()[8]
    ran = {"n": 0}

    def body(seed):
        stream, n_shards, t_high, threshold = sts.make_straddling_case(seed)
        engine = ("dense", "dense_pallas_fused")[seed % 2]
        kw = dict(t_low=0.0, t_high=t_high, threshold=threshold, max_level=3,
                  engine=engine)
        base = mine_arrays(stream, MinerConfig(**kw))
        got = mine_arrays(stream, MinerConfig(
            **kw, mesh=mesh8, n_shards=n_shards, halo=stream.n_events))
        _assert_levels_equal(base, got, ("straddle", engine, seed))
        ran["n"] += 1

    _foreach_seed(body, examples)
    print(f"OK straddle examples={examples} compared={ran['n']}")


def run_halo() -> None:
    from repro.core import MinerConfig, count_fsm_numpy, mine_arrays, serial
    from repro.core.distributed import (build_sharded_index, count_sharded,
                                        count_sharded_batch_indexed)
    from repro.launch.mesh import make_mesh

    mesh2 = make_mesh((2,), ("data",))

    # 1) boundary-timestamp tie: A is shard0's LAST event and shares its
    #    timestamp with shard1's first event; shard1 never sees A, so the
    #    old strict `start < boundary` ownership dropped the occurrence
    types = np.asarray([2, 2, 2, 0, 2, 1, 2, 2], np.int32)   # A=0, B=1
    times = np.asarray([0, 1, 2, 3, 3, 4, 5, 6], np.float32)
    ep = serial([0, 1], 0.0, 1.5)
    want = count_fsm_numpy(types, times, ep)
    assert want == 1
    ty, tm = types.reshape(2, 4), times.reshape(2, 4)
    got, short, ovf = count_sharded(
        jnp.asarray(ty), jnp.asarray(tm), ep, mesh2, n_types=3, halo=4)
    assert int(got) == want and not bool(short) and not bool(ovf), (
        int(got), want, bool(short))

    # 2) halo_end - boundary == span exactly, and the needed B event is a
    #    duplicate timestamp at halo_end just PAST the halo: an undercount
    #    unless flagged (the old `< span` check let it through silently)
    types = np.asarray([2, 2, 2, 0, 2, 2, 1, 2], np.int32)
    times = np.asarray([2, 3, 4, 5, 5, 7, 7, 9], np.float32)
    ep = serial([0, 1], 0.0, 2.0)
    want = count_fsm_numpy(types, times, ep)
    assert want == 1
    ty, tm = types.reshape(2, 4), times.reshape(2, 4)
    got, short, ovf = count_sharded(
        jnp.asarray(ty), jnp.asarray(tm), ep, mesh2, n_types=3, halo=2)
    assert bool(short), "must flag: needed event sits at exactly halo_end"
    got, short, ovf = count_sharded(
        jnp.asarray(ty), jnp.asarray(tm), ep, mesh2, n_types=3, halo=4)
    assert int(got) == want and not bool(short)

    # 3) per-episode flags in the batched path: same stream and halo, one
    #    episode whose span fits the halo and one whose span does not
    index = build_sharded_index(
        jnp.asarray(ty), jnp.asarray(tm), mesh2, n_types=3, halo=2)
    sym = jnp.asarray([[0, 1], [0, 1]], jnp.int32)
    lo = jnp.zeros((2, 1), jnp.float32)
    hi = jnp.asarray([[0.5], [2.0]], jnp.float32)
    counts, _, short_b, ovf_b = count_sharded_batch_indexed(index, sym, lo, hi)
    short_b = np.asarray(short_b)
    assert not short_b[0] and short_b[1], short_b

    # 4) the miner surfaces the flag instead of silently undercounting
    from repro.core.events import EventStream
    stream = EventStream(types, times, 3)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=1, max_level=2,
                      mesh=mesh2, n_shards=2, halo=2)
    try:
        mine_arrays(stream, cfg)
    except RuntimeError as e:
        assert "halo" in str(e), e
    else:
        raise AssertionError("mine_sharded must raise on halo_short")

    # 5) halo=0 on a multi-shard mesh: a boundary-straddling occurrence is
    #    invisible, so the flag must fire (halo is clamped up to 1 neighbor
    #    event exactly so the adequacy check has something to observe)
    types = np.asarray([2, 2, 2, 0, 1, 2, 2, 2], np.int32)
    times = np.asarray([0, 1, 2, 3, 4, 5, 6, 7], np.float32)
    ep = serial([0, 1], 0.0, 1.5)
    assert count_fsm_numpy(types, times, ep) == 1
    ty, tm = types.reshape(2, 4), times.reshape(2, 4)
    got, short, ovf = count_sharded(
        jnp.asarray(ty), jnp.asarray(tm), ep, mesh2, n_types=3, halo=0)
    assert bool(short), "halo=0 with 2 shards must flag, never silently drop"

    # 6) occurrences straddling >= 3 shards are exact via the multi-hop halo
    mesh8 = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 24
    times = np.cumsum(rng.uniform(0.1, 0.5, n)).astype(np.float32)
    types = rng.integers(0, 3, n).astype(np.int32)
    ep = serial([0, 1, 0], 0.0, float(times[-1]))
    want = count_fsm_numpy(types, times, ep)
    ty, tm = types.reshape(8, 3), times.reshape(8, 3)
    got, short, ovf = count_sharded(
        jnp.asarray(ty), jnp.asarray(tm), ep, mesh8, n_types=3, halo=21)
    assert int(got) == want and not bool(short) and not bool(ovf)

    print("OK halo")


def run_corpus(examples: int) -> None:
    import strategies as sts
    from repro.core import MinerConfig, mine_arrays, mine_corpus

    meshes = _meshes()
    ran = {"n": 0}

    def body(seed):
        streams, t_high, thresholds = sts.make_corpus_case(seed)
        n_shards = (1, 2, 8)[seed % 3]
        engine = ("dense", "dense_pallas_fused")[seed % 2]
        kw = dict(t_low=0.0, t_high=t_high, max_level=3, engine=engine)
        res = mine_corpus(
            streams, MinerConfig(threshold=1, mesh=meshes[n_shards], **kw),
            thresholds=thresholds)
        for i, stream in enumerate(streams):
            ref = mine_arrays(
                stream, MinerConfig(threshold=thresholds[i], **kw))
            _assert_levels_equal(
                ref, res.per_stream[i],
                ("corpus", engine, n_shards, seed, i))
        ran["n"] += 1

    _foreach_seed(body, examples)
    print(f"OK corpus examples={examples} compared={ran['n']}")


def run_golden(path: str) -> None:
    from repro.core import MinerConfig, mine_arrays
    from repro.core.events import EventStream
    from repro.launch.mesh import make_mesh

    data = np.load(path)
    stream = EventStream(data["types"], data["times"], int(data["n_types"]))
    mesh8 = make_mesh((8,), ("data",))
    for engine in ("dense", "dense_pallas_fused"):
        cfg = MinerConfig(
            t_low=float(data["t_low"]), t_high=float(data["t_high"]),
            threshold=int(data["threshold"]), max_level=int(data["max_level"]),
            max_candidates=int(data["max_candidates"]), engine=engine,
            mesh=mesh8, n_shards=8, halo=stream.n_events)
        got = mine_arrays(stream, cfg)
        levels = [int(l) for l in data["levels"]]
        assert sorted(got) == levels, (engine, sorted(got), levels)
        for lvl in levels:
            np.testing.assert_array_equal(
                got[lvl].symbols, data[f"level{lvl}_symbols"],
                err_msg=f"{engine} level {lvl}")
            np.testing.assert_array_equal(
                got[lvl].counts, data[f"level{lvl}_counts"],
                err_msg=f"{engine} level {lvl}")
    print("OK golden")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("mode", choices=("differential", "straddle", "halo",
                                     "golden", "corpus"))
    ap.add_argument("--engine", default="dense")
    ap.add_argument("--examples", type=int, default=25)
    ap.add_argument("--golden-path",
                    default=os.path.join(os.path.dirname(__file__), "data",
                                         "golden_stream.npz"))
    args = ap.parse_args()
    if args.mode == "differential":
        run_differential(args.engine, args.examples)
    elif args.mode == "straddle":
        run_straddle(args.examples)
    elif args.mode == "halo":
        run_halo()
    elif args.mode == "corpus":
        run_corpus(args.examples)
    else:
        run_golden(args.golden_path)


if __name__ == "__main__":
    main()
