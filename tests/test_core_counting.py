"""Core engine correctness: every engine vs the numpy FSM oracle."""
import numpy as np
import pytest

from repro.core import (ENGINES, count_all_occurrences_numpy, count_batch,
                        count_fsm_numpy, count_fsm_scan, count_mapconcat,
                        count_nonoverlapped, greedy_numpy, serial)
from repro.core.episodes import Episode, episode_batch
from repro.core.events import EventStream


def random_stream(rng, n=300, n_types=5, rate=1.5):
    times = np.cumsum(rng.exponential(1.0 / rate, size=n)).astype(np.float32)
    types = rng.integers(0, n_types, size=n).astype(np.int32)
    return EventStream(types, times, n_types)


@pytest.fixture(scope="module")
def cases():
    rng = np.random.default_rng(42)
    out = []
    for _ in range(10):
        s = random_stream(rng, n=int(rng.integers(60, 300)),
                          n_types=int(rng.integers(2, 6)))
        n = int(rng.integers(1, 5))
        ep = serial(rng.integers(0, s.n_types, size=n).tolist(),
                    float(rng.uniform(0, 1)), float(rng.uniform(1.5, 5)))
        out.append((s, ep, count_fsm_numpy(s.types, s.times, ep)))
    return out


def test_oracles_agree(cases):
    for s, ep, want in cases:
        st, en = count_all_occurrences_numpy(s.types, s.times, ep)
        assert greedy_numpy(st, en) == want


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_matches_oracle(cases, engine):
    for s, ep, want in cases:
        res = count_nonoverlapped(s, ep, engine=engine,
                                  cap_occ=24 * s.n_events, max_window=128)
        assert not bool(res.overflow), f"overflow {ep}"
        assert int(res.count) == want, f"{engine} {ep}"


def test_parallel_scheduler_matches(cases):
    for s, ep, want in cases:
        res = count_nonoverlapped(s, ep, engine="dense", parallel_schedule=True)
        assert int(res.count) == want


def test_fsm_scan_matches(cases):
    for s, ep, want in cases:
        got = count_fsm_scan(s.types, s.times, ep, ring=16)[0]
        assert int(got) == want


@pytest.mark.slow
def test_mapconcat_matches(cases):
    for s, ep, want in cases:
        got = count_mapconcat(s, ep, n_segments=4, ring=48,
                              occ_per_segment=max(64, s.n_events))
        assert int(got) == want


def test_batch_counting():
    rng = np.random.default_rng(0)
    s = random_stream(rng, n=200, n_types=4)
    eps = [serial(rng.integers(0, 4, size=3).tolist(), 0.2, 3.0)
           for _ in range(7)]
    sym, lo, hi = episode_batch(eps)
    counts, _, overflow = count_batch(
        s.types, s.times, sym, lo, hi, n_types=4, cap=s.n_events)
    assert not bool(np.any(overflow))
    for e, c in zip(eps, np.asarray(counts)):
        assert int(c) == count_fsm_numpy(s.types, s.times, e)


def test_overflow_flagged_not_silent():
    rng = np.random.default_rng(1)
    s = random_stream(rng, n=400, n_types=2, rate=5.0)
    ep = serial([0, 0, 0], 0.0, 5.0)  # dense same-type: superset explodes
    res = count_nonoverlapped(s, ep, engine="count_scan_write",
                              cap_occ=s.n_events, max_window=4)
    assert bool(res.overflow)


def test_empty_and_single_event():
    s = EventStream(np.asarray([1], np.int32), np.asarray([0.5], np.float32), 3)
    ep = serial([1], 0, 1)
    assert int(count_nonoverlapped(s, ep).count) == 1
    ep2 = serial([0, 1], 0.1, 1.0)
    assert int(count_nonoverlapped(s, ep2).count) == 0


def test_episode_validation():
    with pytest.raises(ValueError):
        Episode((0, 1), (0.5,), (0.2,))   # high <= low
    with pytest.raises(ValueError):
        Episode((0, 1), (-1.0,), (2.0,))  # negative low
