"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core import count_fsm_numpy, count_nonoverlapped, serial
from repro.core.telemetry import TelemetryLog, flag_stragglers


def test_paper_pipeline_end_to_end():
    """Simulate -> count -> mine: the full reproduction path on a small
    instance (the paper's §V workflow)."""
    from repro.data.spikes import NetworkConfig, embedded_episodes, simulate
    net = NetworkConfig(n_neurons=12, episode_len=3, n_embedded=1,
                        base_rate=4.0, trigger_hz=10.0, seed=2)
    stream = simulate(net, 6.0)
    truth = embedded_episodes(net)[0]
    res = count_nonoverlapped(stream, truth, engine="dense")
    oracle = count_fsm_numpy(stream.types, stream.times, truth)
    assert int(res.count) == oracle
    assert oracle > 10  # embedded cascade occurs frequently


def test_counting_engines_on_token_streams():
    """The miner runs over LM token streams (MusicGen EnCodec-code stub)."""
    from repro.data.pipeline import token_event_stream
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, size=3000)
    # plant a motif 7 -> 9 -> 11 with gaps
    for i in range(0, 2900, 37):
        toks[i], toks[i + 2], toks[i + 5] = 7, 9, 11
    stream = token_event_stream(toks, 64)
    ep = serial([7, 9, 11], 0.0, 8.0)
    res = count_nonoverlapped(stream, ep, engine="dense")
    assert int(res.count) >= 70


def test_telemetry_straggler_detection():
    log = TelemetryLog()
    for i in range(20):
        log.emit("SLOW:h3", i * 2.0)
        if i % 7 == 0:
            log.emit("SLOW:h1", i * 2.0 + 0.5)
    flagged = flag_stragglers(log, window=5.0, repeat=3, min_count=2)
    assert "h3" in flagged and "h1" not in flagged


def test_serve_loop_smoke():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import Model
    from repro.train import make_serve_step
    cfg = reduced(get_config("stablelm-1.6b"))
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(m), donate_argnums=(1,))
    cache = m.init_cache(2, 32)
    toks = jnp.zeros((2,), jnp.int32)
    for pos in range(8):
        logits, cache = step(params, cache, toks,
                             jnp.full((2,), pos, jnp.int32))
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
