"""Fused batched tracking: kernel vs oracle, engine parity, bench gate.

The fused engine's contract (ISSUE 2): count-identical to the numpy FSM
oracle and to the per-level ``dense_pallas`` engine — including
``n_superset`` and the ``overflow`` flag — with all-padding batch rows and
``window_tiles`` truncation flagged, never silent.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import count_fsm_numpy, count_nonoverlapped, serial
from repro.core.counting import count_batch_indexed
from repro.core.events import EventStream, type_index
from repro.kernels import ops, ref

CAP = 128   # fixed capacity so hypothesis examples share compilations


def _batch_times(rng, b, n, cap, empty_rows=()):
    times = np.full((b, n, cap), np.inf, np.float32)
    for i in range(b):
        for s in range(n):
            if (i, s) in empty_rows:
                continue
            n_real = int(rng.integers(0, cap + 1))
            times[i, s, :n_real] = np.sort(
                rng.uniform(0, 100, n_real)).astype(np.float32)
    return times


# ---------------------------------------------------------------------------
# Kernel level: ops.track_batch vs the quadratic per-level oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cap", [128, 257, 300, 512])   # odd/prime: pad path
@pytest.mark.parametrize("blocks", [(64, 64), (128, 128), (128, 256)])
def test_track_batch_matches_ref(cap, blocks):
    rng = np.random.default_rng(cap)
    b, n = 3, 3
    times = _batch_times(rng, b, n, cap, empty_rows={(1, 1)})
    t_low = rng.uniform(0, 1, (b, n - 1)).astype(np.float32)
    t_high = (t_low + rng.uniform(0.5, 4, (b, n - 1))).astype(np.float32)
    bn, bp = blocks
    starts, nsup, trunc = ops.track_batch(
        jnp.asarray(times), jnp.asarray(t_low), jnp.asarray(t_high),
        block_next=bn, block_prev=bp, interpret=True)
    want, _ = jax.vmap(ref.track_episode_ref)(
        jnp.asarray(times), jnp.asarray(t_low), jnp.asarray(t_high))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(starts))
    assert not np.any(np.asarray(trunc))


def test_track_batch_single_symbol():
    """N=1 episodes: every first-symbol event is an occurrence."""
    rng = np.random.default_rng(0)
    times = _batch_times(rng, 2, 1, 64, empty_rows={(1, 0)})
    starts, nsup, trunc = ops.track_batch(
        jnp.asarray(times), jnp.zeros((2, 0), jnp.float32),
        jnp.zeros((2, 0), jnp.float32), interpret=True)
    finite = np.isfinite(times[:, 0])
    np.testing.assert_array_equal(
        np.asarray(nsup), finite.sum(axis=1).astype(np.int32))
    np.testing.assert_array_equal(
        np.isfinite(np.asarray(starts)) & (np.asarray(starts) > -np.inf),
        finite)


# ---------------------------------------------------------------------------
# Window-tile bounds (vectorized host-side exactness caps)
# ---------------------------------------------------------------------------


def _required_window_tiles_loop(t_prev, t_next, t_high, bn, bp):
    """The pre-vectorization per-tile Python loop, kept as the oracle."""
    cap = t_prev.shape[0]
    nt = cap // bn
    tiles = 1
    for i in range(nt):
        blk = t_next[i * bn:(i + 1) * bn]
        finite = blk[np.isfinite(blk)]
        if finite.size == 0:
            continue
        lo_i = np.searchsorted(t_prev, finite.min() - t_high, side="left")
        hi_i = np.searchsorted(t_prev, finite.max(), side="left")
        tiles = max(tiles, int(hi_i - lo_i) // bp + 2)
    return min(tiles, cap // bp)


@pytest.mark.parametrize("frac", [0.0, 0.3, 1.0])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (128, 128)])
def test_required_window_tiles_matches_loop_oracle(frac, blocks):
    rng = np.random.default_rng(7)
    cap = 512
    bn, bp = blocks
    for t_high in (0.5, 2.0, 50.0):
        t_prev = np.full(cap, np.inf, np.float32)
        t_next = np.full(cap, np.inf, np.float32)
        n_real = int(cap * frac)
        t_prev[:n_real] = np.sort(rng.uniform(0, 100, n_real)).astype(np.float32)
        t_next[:n_real] = np.sort(rng.uniform(0, 100, n_real)).astype(np.float32)
        got = ops.required_window_tiles(t_prev, t_next, t_high, bn, bp)
        want = _required_window_tiles_loop(t_prev, t_next, t_high, bn, bp)
        assert got == want


def test_required_window_tiles_batch_covers_each_level():
    rng = np.random.default_rng(3)
    b, n, cap = 4, 4, 256
    times = _batch_times(rng, b, n, cap, empty_rows={(2, 1)})
    t_high = rng.uniform(0.5, 5, (b, n - 1)).astype(np.float32)
    bn = bp = 64
    got = ops.required_window_tiles_batch(times, t_high, bn, bp)
    per_level = max(
        ops.required_window_tiles(times[i, s], times[i, s + 1],
                                  float(t_high[i, s]), bn, bp)
        for i in range(b) for s in range(n - 1))
    assert got == per_level
    # the bound keeps the fused kernel exact when used as the cap
    starts_cap, _, trunc = ops.track_batch(
        jnp.asarray(times), jnp.zeros((b, n - 1), jnp.float32),
        jnp.asarray(t_high), block_next=bn, block_prev=bp,
        window_tiles=got, interpret=True)
    starts_full, _, _ = ops.track_batch(
        jnp.asarray(times), jnp.zeros((b, n - 1), jnp.float32),
        jnp.asarray(t_high), block_next=bn, block_prev=bp,
        window_tiles=0, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(starts_full), np.asarray(starts_cap))


# ---------------------------------------------------------------------------
# Engine level: fused vs FSM oracle vs per-level dense_pallas (property)
# ---------------------------------------------------------------------------


def _indexed_batch(stream, episodes):
    table, counts = type_index(
        stream.types, stream.times, stream.n_types, CAP)
    n = len(episodes[0].symbols)
    sym = jnp.asarray([e.symbols for e in episodes], jnp.int32)
    lo = jnp.asarray([e.t_low for e in episodes], jnp.float32).reshape(-1, n - 1)
    hi = jnp.asarray([e.t_high for e in episodes], jnp.float32).reshape(-1, n - 1)
    return table, counts, sym, lo, hi


def _run_both(stream, episodes, **kw):
    table, counts, sym, lo, hi = _indexed_batch(stream, episodes)
    fused = count_batch_indexed(table, counts, sym, lo, hi,
                                engine="dense_pallas_fused", **kw)
    level = count_batch_indexed(table, counts, sym, lo, hi,
                                engine="dense_pallas", **kw)
    return [np.asarray(x) for x in fused], [np.asarray(x) for x in level]


def _random_case(seed, n_types=4, batch=4):
    """One seeded (stream, equal-length episode batch) parity case."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 120))
    times = np.cumsum(rng.integers(0, 6, n).astype(np.float32) * 0.25)
    types = rng.integers(0, n_types, n).astype(np.int32)
    stream = EventStream(types, times.astype(np.float32), n_types)
    ep_len = int(rng.integers(2, 5))
    lo = float(rng.uniform(0, 1))
    hi = lo + float(rng.uniform(0.3, 4))
    episodes = [serial(rng.integers(0, n_types, ep_len).tolist(), lo, hi)
                for _ in range(batch)]
    return stream, episodes


def _check_fused_parity(case):
    """Fused == FSM oracle == per-level dense_pallas on counts + n_superset."""
    stream, episodes = case
    (cf, nf, of), (cl, nl, ol) = _run_both(stream, episodes)
    assert not of.any() and not ol.any()
    np.testing.assert_array_equal(cf, cl)
    np.testing.assert_array_equal(nf, nl)
    for e, got in zip(episodes, cf):
        assert int(got) == count_fsm_numpy(stream.types, stream.times, e)


def _check_truncation_parity(case, wt):
    """Truncation caps: the two Pallas engines flag the same episodes, and
    unflagged episodes keep exact counts."""
    stream, episodes = case
    (cf, nf, of), (cl, nl, ol) = _run_both(
        stream, episodes, window_tiles=wt, block_next=32, block_prev=32)
    np.testing.assert_array_equal(of, ol)
    for e, got, flagged in zip(episodes, cf, of):
        if not flagged:
            assert int(got) == count_fsm_numpy(stream.types, stream.times, e)


@pytest.mark.parametrize("seed", range(12))
def test_fused_engine_matches_fsm_oracle_and_dense_pallas(seed):
    _check_fused_parity(_random_case(seed))


@pytest.mark.parametrize("batch", [9, 20])
def test_fused_engine_interpret_chunked_batches(batch):
    """Batches above the interpret-mode chunk size (8) take the lax.map
    path, including ragged tails padded with all-inf rows."""
    _check_fused_parity(_random_case(42, batch=batch))


@pytest.mark.parametrize("seed,wt", [(0, 1), (1, 2), (2, 4), (3, 1), (4, 3)])
def test_fused_overflow_flag_matches_dense_pallas(seed, wt):
    _check_truncation_parity(_random_case(seed + 100), wt)


try:  # hypothesis widens the seeded parity checks when available
    from hypothesis import given, settings, strategies as st
    import strategies as sts  # the shared generators (tests/strategies.py)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(case=sts.stream_and_batch())
    def test_fused_parity_property(case):
        _check_fused_parity(case)

    @settings(max_examples=10, deadline=None)
    @given(case=sts.stream_and_batch(), wt=st.integers(1, 4))
    def test_fused_truncation_parity_property(case, wt):
        _check_truncation_parity(case, wt)


def test_fused_all_padding_batch_row():
    """A symbol with zero events: the whole time row is +inf padding."""
    rng = np.random.default_rng(5)
    n = 80
    types = rng.integers(0, 3, n).astype(np.int32)   # type 3 never occurs
    times = np.cumsum(rng.exponential(0.5, n)).astype(np.float32)
    stream = EventStream(types, times, 4)
    episodes = [serial([0, 3, 1], 0.1, 3.0), serial([0, 1, 2], 0.1, 3.0),
                serial([3, 3, 3], 0.1, 3.0), serial([2, 1, 0], 0.1, 3.0)]
    (cf, nf, of), (cl, nl, ol) = _run_both(stream, episodes)
    assert not of.any()
    np.testing.assert_array_equal(cf, cl)
    np.testing.assert_array_equal(nf, nl)
    assert cf[0] == 0 and cf[2] == 0
    for e, got in zip(episodes, cf):
        assert int(got) == count_fsm_numpy(stream.types, stream.times, e)


def test_fused_truncation_flagged_not_silent():
    """A window covering the whole stream cannot fit one prev tile."""
    rng = np.random.default_rng(9)
    n = 120
    stream = EventStream(rng.integers(0, 2, n).astype(np.int32),
                         np.cumsum(rng.exponential(0.2, n)).astype(np.float32),
                         2)
    episodes = [serial([0, 1], 0.0, 1e6)] * 2
    table, counts, sym, lo, hi = _indexed_batch(stream, episodes)
    for engine in ("dense_pallas_fused", "dense_pallas"):
        _, _, ovf = count_batch_indexed(
            table, counts, sym, lo, hi, engine=engine,
            window_tiles=1, block_next=16, block_prev=16)
        assert np.asarray(ovf).all(), engine


def test_fused_engine_registered_and_in_per_episode_api():
    from repro.core import ENGINES
    assert "dense_pallas_fused" in ENGINES
    rng = np.random.default_rng(2)
    n = 100
    stream = EventStream(rng.integers(0, 4, n).astype(np.int32),
                         np.cumsum(rng.exponential(0.4, n)).astype(np.float32),
                         4)
    ep = serial([0, 1, 2, 3], 0.1, 2.5)
    res = count_nonoverlapped(stream, ep, engine="dense_pallas_fused")
    assert int(res.count) == count_fsm_numpy(stream.types, stream.times, ep)


# ---------------------------------------------------------------------------
# Miner integration + bench compare gate
# ---------------------------------------------------------------------------


def test_mine_fused_engine_and_parallel_schedule_match_dense():
    from repro.core import MinerConfig, mine
    rng = np.random.default_rng(11)
    n = 250
    stream = EventStream(rng.integers(0, 5, n).astype(np.int32),
                         np.cumsum(rng.exponential(0.3, n)).astype(np.float32),
                         5)
    kw = dict(t_low=0.1, t_high=2.0, threshold=12, max_level=3)
    base = mine(stream, MinerConfig(**kw, engine="dense"))
    fused = mine(stream, MinerConfig(**kw, engine="dense_pallas_fused",
                                     parallel_schedule=True))
    assert base.keys() == fused.keys()
    for lvl in base:
        assert base[lvl].episodes == fused[lvl].episodes, lvl
        assert base[lvl].counts == fused[lvl].counts, lvl


def test_bench_compare_entries_gate():
    from benchmarks.run import compare_entries
    cell = dict(episode_len=3, n_events=1024, batch=8, scheduler="scan")
    baseline = [
        {**cell, "engine": "dense", "us_per_call": 100.0},
        {**cell, "engine": "dense_pallas", "us_per_call": 400.0},
    ]
    ok = [
        {**cell, "engine": "dense", "us_per_call": 110.0},
        {**cell, "engine": "dense_pallas", "us_per_call": 900.0},  # not fastest
        {**cell, "engine": "dense_pallas_fused", "us_per_call": 50.0},  # new
    ]
    lines, regressions = compare_entries(baseline, ok)
    assert not regressions
    assert any("(new)" in line for line in lines)
    bad = [{**cell, "engine": "dense", "us_per_call": 130.0}]
    _, regressions = compare_entries(baseline, bad)
    assert len(regressions) == 1 and "dense" in regressions[0]
    # a vanished baseline-fastest engine is an ungated cell, not a pass
    gone = [{**cell, "engine": "dense_pallas", "us_per_call": 380.0}]
    _, regressions = compare_entries(baseline, gone)
    assert len(regressions) == 1 and "missing" in regressions[0]


def test_bench_compare_zero_overlap_is_not_a_pass():
    """A sweep with no cells in common with the baseline (e.g. a smoke run
    against the full checked-in JSON) must not gate vacuously."""
    from benchmarks.run import compare_entries, matched_cells
    baseline = [{"episode_len": 3, "n_events": 1024, "batch": 8,
                 "scheduler": "scan", "engine": "dense", "us_per_call": 100.0}]
    smoke = [{"episode_len": 3, "n_events": 256, "batch": 4,
              "scheduler": "scan", "engine": "dense", "us_per_call": 999.0}]
    _, regressions = compare_entries(baseline, smoke)
    assert regressions and "missing" in regressions[0]
    assert matched_cells(baseline, smoke) == 0
    assert matched_cells(baseline, baseline) == 1
