"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref


def _level_case(rng, cap, frac=0.8):
    n_real = int(cap * frac)
    t_prev = np.full(cap, np.inf, np.float32)
    t_prev[:n_real] = np.sort(rng.uniform(0, 100, n_real)).astype(np.float32)
    t_next = np.full(cap, np.inf, np.float32)
    t_next[:n_real] = np.sort(rng.uniform(0, 100, n_real)).astype(np.float32)
    v_prev = np.where(np.isfinite(t_prev),
                      t_prev - rng.uniform(0, 5, cap).astype(np.float32),
                      -np.inf).astype(np.float32)
    return t_prev, v_prev, t_next


@pytest.mark.parametrize("cap", [128, 256, 512, 1024])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 128), (128, 256)])
def test_episode_track_kernel_shapes(cap, blocks):
    rng = np.random.default_rng(cap)
    t_prev, v_prev, t_next = _level_case(rng, cap)
    lo, hi = 0.5, 4.0
    want = np.asarray(ref.track_level_ref(t_prev, v_prev, t_next, lo, hi))
    bn, bp = blocks
    got = np.asarray(ops.track_level(
        t_prev, v_prev, t_next, lo, hi,
        block_next=bn, block_prev=bp, interpret=True))
    np.testing.assert_array_equal(want, got)


def test_episode_track_windowed_scalar_prefetch():
    rng = np.random.default_rng(0)
    t_prev, v_prev, t_next = _level_case(rng, 1024)
    lo, hi = 0.25, 2.0
    wt = ops.required_window_tiles(t_prev, t_next, hi, 128, 128)
    assert wt < 1024 // 128, "window tiles should prune most of the grid"
    want = np.asarray(ref.track_level_ref(t_prev, v_prev, t_next, lo, hi))
    got = np.asarray(ops.track_level(
        t_prev, v_prev, t_next, lo, hi, block_next=128, block_prev=128,
        window_tiles=wt, interpret=True))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("cap", [97, 127, 251, 300, 509])
def test_episode_track_kernel_odd_caps(cap):
    """Prime/odd capacities keep full-size blocks via tail padding (the old
    largest-divisor fallback degraded block sizes toward 1)."""
    rng = np.random.default_rng(cap)
    t_prev, v_prev, t_next = _level_case(rng, cap)
    want = np.asarray(ref.track_level_ref(t_prev, v_prev, t_next, 0.5, 4.0))
    got = np.asarray(ops.track_level(
        t_prev, v_prev, t_next, 0.5, 4.0,
        block_next=128, block_prev=128, interpret=True))
    assert got.shape == (cap,)
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("frac", [0.0, 0.1, 1.0])
def test_episode_track_padding_extremes(frac):
    rng = np.random.default_rng(3)
    t_prev, v_prev, t_next = _level_case(rng, 256, frac=frac)
    want = np.asarray(ref.track_level_ref(t_prev, v_prev, t_next, 0.5, 3.0))
    got = np.asarray(ops.track_level(t_prev, v_prev, t_next, 0.5, 3.0,
                                     block_next=128, block_prev=128,
                                     interpret=True))
    np.testing.assert_array_equal(want, got)


def test_track_episode_multilevel_matches_core():
    """Kernel-driven multi-level tracking == core dense tracking."""
    from repro.core import events as ev, serial
    rng = np.random.default_rng(5)
    n, n_types = 512, 4
    times = np.cumsum(rng.exponential(0.5, n)).astype(np.float32)
    types = rng.integers(0, n_types, n).astype(np.int32)
    ep = serial([0, 1, 2], 0.2, 3.0)
    table, counts = ev.type_index(types, times, n_types, 512)
    tbs = table[jnp.asarray(ep.symbols)]
    lo = jnp.asarray(ep.t_low); hi = jnp.asarray(ep.t_high)
    starts_k, ends_k = ops.track_episode(tbs, lo, hi, block_next=128,
                                         block_prev=128, interpret=True)
    from repro.core import tracking
    occ = tracking.track_dense(tbs, lo, hi)
    np.testing.assert_allclose(np.asarray(starts_k), np.asarray(occ.starts))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
def test_flash_attention_pallas_vs_oracle(dtype, tol):
    from repro.kernels import flash_attention as fa
    rng = np.random.default_rng(0)
    for (b, s, h, hd, causal) in [(1, 256, 2, 64, True), (2, 128, 4, 32, True),
                                  (1, 256, 2, 64, False)]:
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
        k = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
        v = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
        want = ref.flash_attention_ref(
            q[0], k[0], v[0], causal=causal) if b == 1 else None
        got = fa.flash_attention(q, k, v, causal=causal, block_q=64,
                                 block_kv=64, interpret=True)
        if want is not None:
            np.testing.assert_allclose(
                np.asarray(got[0], np.float32), np.asarray(want, np.float32),
                rtol=tol, atol=tol)
        # cross-check against models/flash oracle for all b
        from repro.models import flash as mflash
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        if causal:
            want2 = mflash.attend_reference(q, k, v, pos, pos, None)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want2, np.float32),
                rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("shape", [(1, 64, 2, 16, 16), (2, 96, 3, 32, 32),
                                   (1, 128, 2, 64, 64)])
def test_wkv_chunk_kernel(shape, dtype, tol):
    from repro.kernels.wkv_chunk import wkv_chunked
    b, t, h, hd, chunk = shape
    rng = np.random.default_rng(hd)
    r = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    lw = jnp.asarray(-rng.uniform(0.01, 1.2, size=(b, t, h, hd)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32) * 0.3
    want = np.asarray(ref.wkv_sequential_ref(r, k, v, lw, u), np.float32)
    got = np.asarray(wkv_chunked(r, k, v, lw, u, chunk=chunk, interpret=True),
                     np.float32)
    scale = max(1.0, float(np.max(np.abs(want))))
    np.testing.assert_allclose(got / scale, want / scale, rtol=tol, atol=tol)
