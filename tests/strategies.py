"""Shared generators + hypothesis strategies for the property suites.

One home for the case generators every property file previously rolled on
its own: random event streams (zero-width gaps produce DUPLICATE
timestamps — the boundary-tie adversary of the sharded ownership rule),
serial episodes with shared or per-gap windows, equal-length episode
batches, and sharded layouts (prime shard lengths so no tiling or padding
path gets a round number to hide behind).

The sharded-case builders (:func:`make_sharded_case`,
:func:`make_straddling_case`) are plain seeded functions so the
differential child process works without hypothesis installed; the
hypothesis composites below wrap them (drawing the seed) when the package
is available, so CI gets shrinking on top of the same distribution.

Import as ``import strategies`` (pytest puts each test file's directory on
``sys.path``); subprocess children add ``tests/`` to ``sys.path`` by hand.
"""
import numpy as np

from repro.core.episodes import Episode, serial
from repro.core.events import EventStream

# shard lengths that are prime (and the shard counts the differential suite
# sweeps): nothing divides evenly, so halo clamping, tail padding, and tile
# rounding all get exercised
PRIME_SHARD_LENS = (2, 3, 5, 7, 11, 13)
SHARD_COUNTS = (1, 2, 8)


def _random_stream(rng, n, n_types, max_gap=5):
    """Zero gaps are common (p = 1/(max_gap+1)) -> duplicate timestamps."""
    gaps = rng.integers(0, max_gap + 1, size=n).astype(np.float32) * 0.25
    times = np.cumsum(gaps).astype(np.float32)
    types = rng.integers(0, n_types, size=n).astype(np.int32)
    return EventStream(types, times, n_types)


def make_sharded_case(seed: int, n_types=4, shard_counts=SHARD_COUNTS):
    """Seeded (stream, n_shards, t_high, threshold) with prime shard lengths.

    The stream length is ``n_shards * n_local - trim`` so the tail shard
    sees 0-2 padding events; duplicate timestamps appear at shard
    boundaries with the same zero-gap mechanism as everywhere else.
    """
    rng = np.random.default_rng(seed)
    n_shards = int(rng.choice(shard_counts))
    n_local = int(rng.choice(PRIME_SHARD_LENS))
    trim = int(rng.integers(0, min(3, n_shards * n_local)))
    n = max(1, n_shards * n_local - trim)
    stream = _random_stream(rng, n, n_types, max_gap=4)
    t_high = float(rng.uniform(0.5, 3.0))
    threshold = int(rng.integers(2, 9))
    return stream, n_shards, t_high, threshold


CORPUS_BATCHES = (1, 2, 5)


def make_corpus_case(seed: int, n_types=4, batches=CORPUS_BATCHES,
                     max_events=40):
    """Seeded (streams, t_high, thresholds): a ragged corpus on one shared
    alphabet.

    Duplicate timestamps come from the usual zero-gap mechanism; every
    third seed forces an all-padding (empty) stream into the corpus, and
    lengths are drawn independently per stream so the padded batch always
    has ragged tails. Thresholds are per stream — the corpus miner must
    apply each stream's own.
    """
    rng = np.random.default_rng(seed)
    batch = int(rng.choice(batches))
    streams = []
    for b in range(batch):
        n = int(rng.integers(1, max_events + 1))
        if seed % 3 == 0 and b == 0:
            n = 0                          # all-padding row in the corpus
        streams.append(_random_stream(rng, n, n_types, max_gap=4))
    t_high = float(rng.uniform(0.5, 3.0))
    thresholds = [int(t) for t in rng.integers(2, 9, size=batch)]
    return streams, t_high, thresholds


def make_straddling_case(seed: int, n_types=3, n_shards=8):
    """Seeded (stream, n_shards, t_high, threshold): occurrences straddle
    >= 3 shards.

    Shards are short (a small prime of events each) and the shared window
    high spans at least three shards' worth of time, so multi-symbol
    occurrences cross several shard boundaries; the multi-hop halo is what
    keeps them exact.
    """
    rng = np.random.default_rng(seed)
    n_local = int(rng.choice((3, 5, 7)))
    n = n_shards * n_local - int(rng.integers(0, 3))
    stream = _random_stream(rng, n, n_types, max_gap=3)
    total = float(np.asarray(stream.times)[-1]) or 1.0
    t_high = max(3.0 * total / n_shards, 0.5)
    threshold = int(rng.integers(2, 7))
    return stream, n_shards, t_high, threshold


try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # the child process runs seeded loops instead
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def streams(draw, max_events=120, max_types=4, min_events=1):
        """Random time-sorted stream; zero gaps -> duplicate timestamps."""
        n_types = draw(st.integers(2, max_types))
        n = draw(st.integers(min_events, max_events))
        gaps = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
        times = np.cumsum(np.asarray(gaps, np.float32) * 0.25)
        types = np.asarray(
            draw(st.lists(st.integers(0, n_types - 1), min_size=n, max_size=n)),
            np.int32)
        return EventStream(types, times.astype(np.float32), n_types)

    @st.composite
    def episodes(draw, n_types=4, min_len=1, max_len=4):
        """Serial episode with one shared (lo, lo+width] window per gap."""
        n = draw(st.integers(min_len, max_len))
        syms = draw(st.lists(st.integers(0, n_types - 1),
                             min_size=n, max_size=n))
        lo = draw(st.floats(0.0, 1.0))
        width = draw(st.floats(0.3, 4.0))
        return serial(syms, lo, lo + width)

    @st.composite
    def per_gap_episodes(draw, n_types=4, min_len=2, max_len=4):
        """Serial episode whose every gap draws its own (lo, hi] window."""
        n = draw(st.integers(min_len, max_len))
        syms = draw(st.lists(st.integers(0, n_types - 1),
                             min_size=n, max_size=n))
        lows = [draw(st.floats(0.0, 1.0)) for _ in range(n - 1)]
        highs = [lo + draw(st.floats(0.3, 4.0)) for lo in lows]
        return Episode(tuple(syms), tuple(lows), tuple(highs))

    @st.composite
    def stream_and_batch(draw, max_events=120, n_types=4, batch=4,
                         min_ep_len=2, max_ep_len=4):
        """A stream plus an equal-length episode batch (fused parity)."""
        s = draw(streams(max_events=max_events, max_types=n_types))
        s = EventStream(s.types, s.times, n_types)      # fixed alphabet
        ep_len = draw(st.integers(min_ep_len, max_ep_len))
        lo = draw(st.floats(0.0, 1.0))
        width = draw(st.floats(0.3, 4.0))
        eps = [
            serial(draw(st.lists(st.integers(0, n_types - 1),
                                 min_size=ep_len, max_size=ep_len)),
                   lo, lo + width)
            for _ in range(batch)
        ]
        return s, eps

    def seeds():
        """Seed stream for the seeded case builders above — hypothesis
        drives (and shrinks) the seed, the builder shapes the case."""
        return st.integers(0, 2**31 - 1)


def clamp_episode(ep: Episode, n_types: int) -> Episode:
    """Fold an episode's symbols into a (possibly smaller) alphabet."""
    return Episode(tuple(s % n_types for s in ep.symbols), ep.t_low, ep.t_high)
