"""Autotune bucket table: deterministic resolution, fallbacks, precedence.

The tuned-tile table is load-bearing for the hot path (every counting entry
resolves ``None`` knobs through it), so its failure modes are pinned here:
a missing or malformed ``tuned_configs.json`` must silently reproduce the
pre-autotune defaults, explicit caller integers must always win, and the
same (kind, L, N, B) must always land in the same bucket.
"""
import json

import pytest

from repro.kernels import autotune
from repro.kernels.autotune import DEFAULTS, TileConfig


@pytest.fixture(autouse=True)
def _fresh_table_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# Bucket keys
# ---------------------------------------------------------------------------


def test_bucket_key_format_and_pow2_rounding():
    assert autotune.bucket_key("count", 3, 1024, 8) == "count:L3:N1024:B8"
    assert autotune.bucket_key("track", 5, 1000, 7) == "track:L5:N1024:B8"
    assert autotune.bucket_key("count", 2, 1025, 9) == "count:L2:N2048:B16"
    assert autotune.bucket_key("count", 1, 1, 1) == "count:L1:N1:B1"


def test_bucket_key_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown kernel kind"):
        autotune.bucket_key("fuse", 3, 128, 8)


def test_bucket_key_deterministic():
    keys = {autotune.bucket_key("count", 4, 4096, 32) for _ in range(50)}
    assert len(keys) == 1


# ---------------------------------------------------------------------------
# resolve(): table entry > defaults, explicit overrides > everything
# ---------------------------------------------------------------------------


def test_resolve_missing_table_falls_back_to_defaults(tmp_path):
    cfg = autotune.resolve("count", 3, 128, 8,
                           path=str(tmp_path / "absent.json"))
    assert cfg == DEFAULTS["count"]
    assert cfg == TileConfig(256, 256, 0, 8)


def test_resolve_malformed_table_falls_back_to_defaults(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    assert autotune.resolve("track", 4, 256, 16, path=str(p)) \
        == DEFAULTS["track"]


def test_resolve_missing_bucket_falls_back_to_defaults(tmp_path):
    p = tmp_path / "t.json"
    p.write_text(json.dumps(
        {"configs": {"count:L9:N8:B8": {"block_next": 8}}}))
    assert autotune.resolve("count", 3, 128, 8, path=str(p)) \
        == DEFAULTS["count"]


def test_resolve_uses_tuned_entry_and_fills_missing_fields(tmp_path):
    p = tmp_path / "t.json"
    key = autotune.bucket_key("count", 3, 128, 8)
    p.write_text(json.dumps(
        {"configs": {key: {"block_next": 8, "block_prev": 16}}}))
    cfg = autotune.resolve("count", 3, 128, 8, path=str(p))
    assert (cfg.block_next, cfg.block_prev) == (8, 16)
    # fields absent from the entry come from DEFAULTS
    assert cfg.window_tiles == DEFAULTS["count"].window_tiles
    assert cfg.chunk == DEFAULTS["count"].chunk


def test_resolve_explicit_overrides_beat_tuned_entry(tmp_path):
    p = tmp_path / "t.json"
    key = autotune.bucket_key("count", 3, 128, 8)
    p.write_text(json.dumps({"configs": {key: {
        "block_next": 8, "block_prev": 8, "window_tiles": 2, "chunk": 16}}}))
    cfg = autotune.resolve("count", 3, 128, 8, block_prev=64, chunk=4,
                           path=str(p))
    assert cfg == TileConfig(block_next=8, block_prev=64,
                             window_tiles=2, chunk=4)


def test_resolve_deterministic_across_calls(tmp_path):
    p = tmp_path / "t.json"
    key = autotune.bucket_key("track", 4, 4096, 32)
    p.write_text(json.dumps({key: {"block_next": 32, "block_prev": 32}}))
    got = {autotune.resolve("track", 4, 4096, 32, path=str(p))
           for _ in range(20)}
    assert got == {TileConfig(32, 32, 0, 8)}


# ---------------------------------------------------------------------------
# Checked-in table (when present) is well-formed and bucket-key addressed
# ---------------------------------------------------------------------------


def test_checked_in_table_entries_are_valid_buckets():
    table = autotune.load_table()
    fields = {"block_next", "block_prev", "window_tiles", "chunk"}
    for key, entry in table.items():
        kind, lpart, npart, bpart = key.split(":")
        assert kind in DEFAULTS
        levels = int(lpart[1:])
        cap = int(npart[1:])
        batch = int(bpart[1:])
        assert autotune.bucket_key(kind, levels, cap, batch) == key
        assert set(entry) <= fields
        assert all(isinstance(v, int) and v >= 0 for v in entry.values())
        cfg = autotune.resolve(kind, levels, cap, batch)
        for f in fields:
            want = entry.get(f, getattr(DEFAULTS[kind], f))
            assert getattr(cfg, f) == want


# ---------------------------------------------------------------------------
# Cost model: sane, deterministic ranking
# ---------------------------------------------------------------------------


def test_candidate_configs_respect_cap_and_kind():
    count_cands = autotune.candidate_configs("count", 64, 32)
    assert all(c.block_next <= 64 and c.block_prev <= 64
               for c in count_cands)
    assert all(c.window_tiles == 0 for c in count_cands)
    assert {c.chunk for c in count_cands} == {8, 16, 32}
    track_cands = autotune.candidate_configs("track", 64, 32)
    assert {c.chunk for c in track_cands} == {DEFAULTS["track"].chunk}


def test_model_time_positive_and_deterministic():
    cfg = TileConfig(8, 8, 0, 8)
    t1 = autotune.model_time("count", 3, 1024, 8, cfg)
    t2 = autotune.model_time("count", 3, 1024, 8, cfg)
    assert t1 == t2 > 0.0


def test_rank_candidates_deterministic_shortlist():
    a = autotune.rank_candidates("count", 3, 1024, 8, top_k=4)
    b = autotune.rank_candidates("count", 3, 1024, 8, top_k=4)
    assert a == b
    assert 1 <= len(a) <= 4
    assert all(isinstance(c, TileConfig) for c in a)
