"""Hypothesis property tests for the counting system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import strategies as sts
from repro.core import (count_fsm_numpy, count_nonoverlapped, serial)
from repro.core.events import EventStream


@pytest.mark.parametrize("engine", ["dense", "dense_pallas", "dense_pallas_fused"])
@settings(max_examples=40, deadline=None)
@given(s=sts.streams(), ep=sts.episodes())
def test_dense_matches_fsm_oracle(engine, s, ep):
    ep = sts.clamp_episode(ep, s.n_types)
    want = count_fsm_numpy(s.types, s.times, ep)
    # dense_pallas runs the Pallas kernel in interpret mode on CPU
    got = count_nonoverlapped(s, ep, engine=engine)
    assert int(got.count) == want


@settings(max_examples=25, deadline=None)
@given(s=sts.streams(), ep=sts.per_gap_episodes())
def test_per_gap_windows_match_fsm_oracle(s, ep):
    """Heterogeneous (per-gap) constraint windows: dense vs the FSM oracle."""
    ep = sts.clamp_episode(ep, s.n_types)
    want = count_fsm_numpy(s.types, s.times, ep)
    got = count_nonoverlapped(s, ep, engine="dense")
    assert int(got.count) == want


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 4))
def test_candidate_join_matches_reference(seed, n):
    """Array-based suffix/prefix join == the list-based reference join."""
    from repro.core import MinerConfig
    from repro.core.episodes import Episode
    from repro.core.mining import generate_candidates, generate_candidates_arrays
    rng = np.random.default_rng(seed)
    rows = np.unique(rng.integers(0, 5, size=(12, n)), axis=0).astype(np.int32)
    rng.shuffle(rows)
    cfg = MinerConfig(t_low=0.1, t_high=2.0, threshold=1, max_candidates=4096)
    frequent = [Episode(tuple(int(x) for x in r),
                        (cfg.t_low,) * (n - 1), (cfg.t_high,) * (n - 1))
                for r in rows]
    want = generate_candidates(frequent, n + 1, cfg)
    got = generate_candidates_arrays(rows, n + 1, cfg)
    assert [e.symbols for e in want] == [tuple(int(x) for x in r) for r in got]


@settings(max_examples=25, deadline=None)
@given(sts.streams(), sts.episodes())
def test_count_bounded_by_min_symbol_count(s, ep):
    """Non-overlapped count <= events of the rarest symbol in the episode."""
    ep = serial([x % s.n_types for x in ep.symbols], 0.0, 2.0)
    counts = np.bincount(np.asarray(s.types), minlength=s.n_types)
    bound = min(counts[list(ep.symbols)])
    got = int(count_nonoverlapped(s, ep, engine="dense").count)
    assert got <= bound


@settings(max_examples=25, deadline=None)
@given(sts.streams(), sts.episodes(), st.floats(0.1, 10.0))
def test_time_scale_invariance(s, ep, scale):
    """Scaling all times and windows by the same factor preserves counts."""
    ep = serial([x % s.n_types for x in ep.symbols], 0.25, 2.25)
    base = int(count_nonoverlapped(s, ep, engine="dense").count)
    s2 = EventStream(s.types, (np.asarray(s.times) * scale).astype(np.float32),
                     s.n_types)
    ep2 = serial(list(ep.symbols), 0.25 * scale, 2.25 * scale)
    got = int(count_nonoverlapped(s2, ep2, engine="dense").count)
    # float32 rounding at window boundaries can flip an inclusion; allow 1
    assert abs(got - base) <= 1


@settings(max_examples=25, deadline=None)
@given(sts.streams())
def test_anti_monotonicity(s):
    """count(alpha) >= count(alpha extended by one symbol)."""
    ep2 = serial([0, 1], 0.0, 2.0)
    ep3 = serial([0, 1, 0], 0.0, 2.0)
    c2 = int(count_nonoverlapped(s, ep2, engine="dense").count)
    c3 = int(count_nonoverlapped(s, ep3, engine="dense").count)
    assert c2 >= c3


@settings(max_examples=20, deadline=None)
@given(sts.streams(), sts.episodes())
def test_engines_consistent(s, ep):
    ep = serial([x % s.n_types for x in ep.symbols], 0.25, 2.0)
    dense = count_nonoverlapped(s, ep, engine="dense")
    csw = count_nonoverlapped(s, ep, engine="count_scan_write",
                              cap_occ=32 * max(s.n_events, 4), max_window=128)
    if not bool(csw.overflow):
        assert int(dense.count) == int(csw.count)
