"""MiningPlan dispatch spine + AOT executable cache (DESIGN.md §11).

The O(#buckets) compile gate: K distinct input shapes falling into k
capacity-class buckets must trace each cached counting function exactly k
times — with bit-for-bit result parity against the uncached path across
engines x schedulers — plus the cache-behavior contract (LRU bound, warm
idempotency, shared executables across streaming sessions, warned fallback
for uncacheable plans) and the one-rounding-rule regression against every
checked-in tuned_configs.json bucket.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import EventStream, MinerConfig, StreamingMiner, mine_arrays
from repro.core import corpus as corpus_lib
from repro.core import counting, events
from repro.core import plan
from repro.kernels import autotune


@pytest.fixture(autouse=True)
def fresh_cache():
    plan.reset_cache()
    plan.reset_trace_counts()
    yield
    plan.reset_cache()
    plan.reset_trace_counts()


def _stream(n, n_types=4, seed=0, t_max=None):
    """Round-robin types (every type present) with sorted random times."""
    rng = np.random.default_rng(seed)
    types = (np.arange(n) % n_types).astype(np.int32)
    rng.shuffle(types)
    times = np.sort(rng.uniform(0.0, t_max or n * 0.05, n)).astype(np.float32)
    return EventStream(types, times, n_types)


def _flat(results):
    return {lvl: (la.symbols.tolist(), la.counts.tolist(), la.n_candidates)
            for lvl, la in results.items()}


# ---------------------------------------------------------------------------
# One rounding rule, one bucket scheme
# ---------------------------------------------------------------------------


def test_rounding_rule_single_source():
    # autotune's bucket rounding IS plan.pow2_ceil — same object, not a copy
    assert autotune.pow2_ceil is plan.pow2_ceil
    assert autotune._pow2_ceil is plan.pow2_ceil
    for raw, rounded in [(0, 1), (1, 1), (2, 2), (3, 4), (8, 8), (9, 16),
                         (1000, 1024), (1025, 2048)]:
        assert plan.pow2_ceil(raw) == rounded
    # idempotent: rounding before bucket_key changes nothing
    for cap, batch in [(33, 5), (64, 16), (1000, 7)]:
        assert (autotune.bucket_key("count", 2, cap, batch)
                == autotune.bucket_key("count", 2, plan.pow2_ceil(cap),
                                       plan.pow2_ceil(batch)))
    assert plan.capacity_class(5, floor=16) == 16
    assert plan.capacity_class(17, floor=16) == 32


def test_every_tuned_bucket_reachable_from_a_plan():
    """Regression: each checked-in tuned_configs.json bucket is the bucket
    of some MiningPlan, so tuning and plan bucketing cannot drift apart."""
    table = autotune.load_table()
    assert table, "tuned_configs.json went missing or empty"
    engine_for = {"count": "dense_pallas_fused", "track": "dense"}
    for key in table:
        kind, lvl, cap, batch = key.split(":")
        levels, cap, batch = int(lvl[1:]), int(cap[1:]), int(batch[1:])
        p = plan.plan_for(
            "count_indexed", level=levels + 1, n_types=4, cap=cap,
            batch=batch, engine=engine_for[kind])
        assert p.kind == kind, key
        assert p.autotune_key() == key, key
        # and the plan carries exactly the tiles that bucket tunes
        tc = autotune.resolve(kind, levels, cap, batch)
        assert (p.block_next, p.block_prev, p.window_tiles, p.chunk) == (
            tc.block_next, tc.block_prev, tc.window_tiles, tc.chunk), key


# ---------------------------------------------------------------------------
# The O(#buckets) trace gate (tentpole acceptance)
# ---------------------------------------------------------------------------

# 8 distinct lengths in exactly 2 capacity classes (64 and 128): K > 3*k
RAGGED_LENGTHS = (33, 40, 47, 60, 70, 90, 100, 120)


@pytest.mark.parametrize("engine", ["dense", "dense_pallas_fused"])
@pytest.mark.parametrize("parallel", [False, True])
def test_mine_arrays_compiles_per_bucket(engine, parallel):
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_level=2,
                      engine=engine, parallel_schedule=parallel)
    plan.reset_trace_counts()
    plan.reset_cache()
    cached = {}
    for n in RAGGED_LENGTHS:
        cached[n] = mine_arrays(_stream(n, seed=n), cfg)
    # threshold=1 + every type present => the level-2 batch is always
    # n_types^2 = 16: one batch class, two cap classes => exactly 2 traces
    assert plan.trace_counts() == {"count_indexed": 2}
    stats = plan.cache_stats()
    assert stats["misses"] == 2
    assert stats["hits"] == len(RAGGED_LENGTHS) - 2
    # a second ragged pass over every shape compiles NOTHING new
    for n in RAGGED_LENGTHS:
        again = mine_arrays(_stream(n, seed=n), cfg)
        assert _flat(again) == _flat(cached[n])
    assert plan.trace_counts() == {"count_indexed": 2}
    # bit-for-bit parity with the uncached path
    for n in RAGGED_LENGTHS:
        with plan.cache_disabled():
            ref = mine_arrays(_stream(n, seed=n), cfg)
        assert _flat(ref) == _flat(cached[n])


def test_mine_corpus_compiles_per_bucket():
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_level=2)
    corpora = [
        [_stream(60, seed=s) for s in range(3)],   # S=3 -> class 4
        [_stream(60, seed=s) for s in range(4)],   # S=4 -> class 4 (shared)
        [_stream(60, seed=s) for s in range(5)],   # S=5 -> class 8
    ]
    plan.reset_trace_counts()
    results = [corpus_lib.mine_corpus(c, cfg) for c in corpora]
    assert plan.trace_counts() == {"count_corpus": 2}
    with plan.cache_disabled():
        ref = corpus_lib.mine_corpus(corpora[0], cfg)
    for got, want in zip(results[0].per_stream, ref.per_stream):
        assert _flat(got) == _flat(want)


def test_streaming_sessions_share_one_executable():
    """Same-bucket appends across concurrent miners: zero extra compiles."""
    cfg = MinerConfig(t_low=0.0, t_high=0.5, threshold=1, max_level=2)
    chunks = [_stream(16, seed=7, t_max=0.8)]
    base = chunks[0]
    for i in range(1, 4):   # identical-shape chunks, shifted in time
        chunks.append(EventStream(base.types, base.times + i * 0.8, 4))

    def run(miner):
        out = None
        for c in chunks:
            out = miner.append(c.types, c.times)
        return out

    plan.reset_trace_counts()
    m1 = StreamingMiner(4, cfg, initial_cap=64)
    out1 = run(m1)
    t_after_one = plan.trace_counts()
    assert t_after_one.get("count_stateful", 0) >= 1    # cold backfill
    assert t_after_one.get("count_tail", 0) >= 1        # warm tail recount
    # a second session over the same bucket compiles NOTHING new ...
    m2 = StreamingMiner(4, cfg, initial_cap=64)
    out2 = run(m2)
    assert plan.trace_counts() == t_after_one
    assert _flat(out2) == _flat(out1)
    # ... and interleaved appends (concurrent sessions) don't either
    m3 = StreamingMiner(4, cfg, initial_cap=64)
    m4 = StreamingMiner(4, cfg, initial_cap=64)
    for c in chunks:
        m3.append(c.types, c.times)
        m4.append(c.types, c.times)
    assert plan.trace_counts() == t_after_one


# ---------------------------------------------------------------------------
# Cache behavior: LRU bound, warm, fallback
# ---------------------------------------------------------------------------


def _indexed_case(n_events, batch, seed=0):
    s = _stream(n_events, seed=seed)
    table, counts = events.type_index(s.types, s.times, s.n_types, n_events)
    sym = np.stack([np.arange(batch) % 4,
                    (np.arange(batch) + 1) % 4], axis=1).astype(np.int32)
    lo = np.zeros((batch, 1), np.float32)
    hi = np.full((batch, 1), 1.0, np.float32)
    return table, counts, sym, lo, hi


def test_lru_eviction_honors_bound_and_retraces_once():
    plan.reset_cache(maxsize=2)
    cases = {n: _indexed_case(n, 8, seed=n) for n in (30, 60, 120)}  # 3 caps
    first = {n: counting.count_batch_indexed(*c) for n, c in cases.items()}
    stats = plan.cache_stats()
    assert stats["size"] == 2
    assert stats["misses"] == 3
    assert stats["evictions"] == 1          # bucket 32 evicted by 128
    p32 = plan.plan_for("count_indexed", level=2, n_types=4, cap=30, batch=8)
    assert plan.plan_trace_counts()[p32] == 1
    # the evicted bucket returns: exactly one re-trace, then cached again
    again = counting.count_batch_indexed(*cases[30])
    assert plan.plan_trace_counts()[p32] == 2
    for a, b in zip(again, first[30]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert plan.cache_stats()["evictions"] == 2      # 64 made room
    counting.count_batch_indexed(*cases[30])
    assert plan.plan_trace_counts()[p32] == 2        # hit, no re-trace
    # shrinking the bound evicts immediately
    plan.set_cache_size(1)
    assert plan.cache_stats()["size"] == 1


def test_warm_is_idempotent_and_primes_real_calls():
    p = plan.plan_for("count_indexed", level=2, n_types=4, cap=60, batch=8)
    assert plan.warm([p]) == {"compiled": 1, "cached": 0, "skipped": 0}
    assert plan.warm([p]) == {"compiled": 0, "cached": 1, "skipped": 0}
    assert plan.plan_trace_counts()[p] == 1
    # a real call in that bucket is a pure hit: no compile, no miss
    out = counting.count_batch_indexed(*_indexed_case(60, 8))
    assert plan.cache_stats()["misses"] == 0
    assert plan.cache_stats()["hits"] == 1
    assert plan.plan_trace_counts()[p] == 1
    with plan.cache_disabled():
        ref = counting.count_batch_indexed(*_indexed_case(60, 8))
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_oversized_plan_falls_back_with_warning(monkeypatch):
    case = _indexed_case(60, 8)
    with plan.cache_disabled():
        ref = counting.count_batch_indexed(*case)
    monkeypatch.setattr(plan, "MAX_CACHE_BATCH", 4)
    with pytest.warns(UserWarning, match="not cacheable"):
        out = counting.count_batch_indexed(*case)
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    stats = plan.cache_stats()
    assert stats["fallbacks"] == 1
    assert stats["size"] == 0               # nothing cached


def test_malformed_plan_is_uncacheable_not_fatal():
    bad = plan.MiningPlan(fn="count_indexed", level=1, n_types=4,
                          cap=64, batch=8)
    assert "malformed" in plan.uncacheable_reason(bad)
    tail0 = plan.MiningPlan(fn="count_tail", level=2, n_types=4, cap=64,
                            batch=8, tail_cap=0)
    assert "tail" in plan.uncacheable_reason(tail0)
    ok = plan.plan_for("count_indexed", level=2, n_types=4, cap=64, batch=8)
    assert plan.uncacheable_reason(ok) is None
    with pytest.warns(UserWarning, match="warm: skipping"):
        assert plan.warm([bad]) == {"compiled": 0, "cached": 0, "skipped": 1}


# ---------------------------------------------------------------------------
# Bucket padding must not weaken semantics
# ---------------------------------------------------------------------------


def test_build_cap_preserves_overflow_detection():
    """A table padded from its build width (10) to its class (16) must
    still flag per-type overflow against the BUILD width."""
    n = 60
    s = _stream(n, seed=3)
    table, counts = events.type_index(s.types, s.times, s.n_types, 10)
    assert int(np.asarray(counts).max()) > 10   # truly overflowing
    sym = np.array([[0, 1]], np.int32)
    lo = np.zeros((1, 1), np.float32)
    hi = np.ones((1, 1), np.float32)
    _, _, overflow = counting.count_batch_indexed(table, counts, sym, lo, hi)
    assert bool(np.asarray(overflow)[0])
    # sanity: a wide-enough build does not flag
    table2, counts2 = events.type_index(s.types, s.times, s.n_types, n)
    _, _, ov2 = counting.count_batch_indexed(table2, counts2, sym, lo, hi)
    assert not bool(np.asarray(ov2)[0])


def test_plans_for_miner_covers_a_cold_mine():
    """warm(plans_for_miner(...)) => the first mine_arrays pays 0 compiles."""
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_level=2)
    s = _stream(60, seed=11)
    plans = plan.plans_for_miner(cfg, n_types=4, n_events=60)
    plan.warm(plans)
    warmed_traces = dict(plan.trace_counts())
    mine_arrays(s, cfg)
    assert plan.trace_counts() == warmed_traces   # zero new compiles
    assert plan.cache_stats()["misses"] == 0
