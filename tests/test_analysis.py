"""HLO cost parser: trip counting, collective bytes, roofline math."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import roofline as rl
from repro.analysis.hlo_costs import module_costs


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def _cost_analysis(c):
    ca = c.cost_analysis()
    # older jax returns a one-element list of dicts, newer a dict
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_flops_match_cost_analysis_no_while():
    def f(x, w):
        return jnp.tanh(x @ w) @ w
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 256), jnp.float32))
    got = module_costs(c.as_text())["flops"]
    assert got == _cost_analysis(c)["flops"]


def test_while_trip_multiplication():
    def f(x, ws):
        return lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((9, 256, 256), jnp.float32))
    got = module_costs(c.as_text())["flops"]
    assert got == 9 * 2 * 128 * 256 * 256
    # cost_analysis undercounts (body once) — the reason this parser exists;
    # jax versions differ by a few non-matmul flops, so compare with slack
    ca = _cost_analysis(c)["flops"]
    assert abs(ca - 2 * 128 * 256 * 256) / (2 * 128 * 256 * 256) < 0.01


def test_nested_while():
    def f(x, ws):
        def outer(c, w):
            inner = lax.scan(lambda ci, _: (jnp.tanh(ci @ w), None), c,
                             None, length=5)[0]
            return inner, None
        return lax.scan(outer, x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((3, 128, 128), jnp.float32))
    got = module_costs(c.as_text())["flops"]
    assert got == 3 * 5 * 2 * 64 * 128 * 128


def test_op_mix_nonempty():
    def f(x):
        return jnp.sum(jnp.exp(x))
    c = _compile(f, jax.ShapeDtypeStruct((128,), jnp.float32))
    mix = module_costs(c.as_text())["op_mix"]
    assert sum(mix.values()) >= 1


def test_roofline_terms_and_bottleneck():
    r = rl.analyze(
        arch="x", shape="train_4k", mesh_name="16x16", chips=256,
        cost={"flops": 1.97e14, "bytes accessed": 8.19e11},
        coll={"total": 5e10}, model_flops=1.97e14 * 256 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-6
    assert abs(r.t_memory - 1.0) < 1e-6
    assert abs(r.t_collective - 1.0) < 1e-6
    assert r.useful_ratio == 0.5
    r2 = rl.analyze(arch="x", shape="s", mesh_name="m", chips=1,
                    cost={"flops": 1.0, "bytes accessed": 1e15},
                    coll={"total": 0.0}, model_flops=1.0)
    assert r2.bottleneck == "memory"


def test_collective_bytes_from_sharded_module():
    if jax.device_count() < 2:
        # single-device runs cannot produce partitioned collectives; the
        # multi-device path is covered by tests/test_multidevice.py
        return
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((jax.device_count(),), ("model",))
    def f(x, w):
        return x @ w

    def sh(*s):
        return NamedSharding(mesh, P(*s))
    c = jax.jit(f, in_shardings=(sh(None, "model"), sh("model", None)),
                out_shardings=sh(None, None)).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    coll = module_costs(c.as_text())["coll"]
    assert coll.get("total", 0) > 0
