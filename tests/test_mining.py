"""Device-resident miner: seed-parity, join equivalence, index reuse."""
import numpy as np
import pytest

from repro.core import MinerConfig, generate_candidates, mine, mine_arrays
from repro.core.episodes import Episode
from repro.core.events import EventStream
from repro.core.mining import (LevelResult, count_candidates,
                               generate_candidates_arrays)


def _random_stream(seed=7, n=400, n_types=6, rate=0.3):
    rng = np.random.default_rng(seed)
    return EventStream(
        rng.integers(0, n_types, n).astype(np.int32),
        np.cumsum(rng.exponential(rate, n)).astype(np.float32),
        n_types)


def _mine_seed_reference(stream, cfg):
    """The seed repo's list-based miner, kept verbatim as the parity oracle."""
    results = {}
    types = np.asarray(stream.types)
    level1_eps, level1_counts = [], []
    binc = np.bincount(types, minlength=stream.n_types)
    for t in range(stream.n_types):
        if binc[t] >= cfg.threshold:
            level1_eps.append(Episode((t,)))
            level1_counts.append(int(binc[t]))
    results[1] = LevelResult(level1_eps, level1_counts, stream.n_types)
    frequent = level1_eps
    for level in range(2, cfg.max_level + 1):
        if not frequent:
            break
        cands = generate_candidates(frequent, level, cfg)
        if not cands:
            results[level] = LevelResult([], [], 0)
            break
        counts = count_candidates(stream, cands, cfg)
        thr = (cfg.level_thresholds or {}).get(level, cfg.threshold)
        keep = [(e, int(c)) for e, c in zip(cands, counts) if c >= thr]
        results[level] = LevelResult(
            [e for e, _ in keep], [c for _, c in keep], len(cands))
        frequent = [e for e, _ in keep]
    return results


@pytest.mark.parametrize("threshold,max_level", [(20, 4), (35, 3), (8, 5)])
def test_mine_matches_seed_reference(threshold, max_level):
    """Fixed-seed regression: level-for-level identical episodes/counts."""
    s = _random_stream()
    cfg = MinerConfig(t_low=0.1, t_high=2.5, threshold=threshold,
                      max_level=max_level, max_candidates=300)
    got = mine(s, cfg)
    want = _mine_seed_reference(s, cfg)
    assert got.keys() == want.keys()
    for lvl in want:
        assert got[lvl].n_candidates == want[lvl].n_candidates, lvl
        assert got[lvl].episodes == want[lvl].episodes, lvl
        assert got[lvl].counts == want[lvl].counts, lvl


def test_mine_with_level_thresholds_matches_seed():
    s = _random_stream(seed=3)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=10,
                      level_thresholds={2: 30, 3: 12}, max_level=4)
    got = mine(s, cfg)
    want = _mine_seed_reference(s, cfg)
    assert got.keys() == want.keys()
    for lvl in want:
        assert got[lvl].episodes == want[lvl].episodes
        assert got[lvl].counts == want[lvl].counts


def test_candidate_join_arrays_match_reference():
    rng = np.random.default_rng(0)
    cfg = MinerConfig(t_low=0.1, t_high=2.0, threshold=1, max_candidates=4096)
    for n in (2, 3, 4):
        rows = np.unique(rng.integers(0, 4, size=(25, n)), axis=0).astype(np.int32)
        rng.shuffle(rows)
        frequent = [Episode(tuple(int(x) for x in r),
                            (cfg.t_low,) * (n - 1), (cfg.t_high,) * (n - 1))
                    for r in rows]
        want = [e.symbols for e in generate_candidates(frequent, n + 1, cfg)]
        got = generate_candidates_arrays(rows, n + 1, cfg)
        assert want == [tuple(int(x) for x in r) for r in got]


def test_candidate_join_truncation_matches_reference():
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_candidates=7)
    rows = np.asarray([[a, b] for a in range(4) for b in range(4)], np.int32)
    frequent = [Episode((int(a), int(b)), (0.0,), (1.0,)) for a, b in rows]
    want = [e.symbols for e in generate_candidates(frequent, 3, cfg)]
    got = generate_candidates_arrays(rows, 3, cfg)
    assert len(want) == 7 == got.shape[0]
    assert want == [tuple(int(x) for x in r) for r in got]


def test_index_built_once_per_stream(monkeypatch):
    """mine() must build the per-type index once, not once per level."""
    from repro.core import events as events_lib
    calls = {"n": 0}
    real = events_lib.type_index

    def counting_type_index(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    import repro.core.mining as mining_mod
    monkeypatch.setattr(mining_mod.events_lib, "type_index", counting_type_index)
    s = _random_stream(seed=1, n=200)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=8, max_level=4)
    res = mine(s, cfg)
    assert max(res) >= 3, "want a multi-level run for this check to bite"
    assert calls["n"] == 1


@pytest.mark.slow
@pytest.mark.parametrize("engine", ["dense_pallas", "count_scan_write"])
def test_mine_engine_agreement(engine):
    """Every registered engine drives the miner to the same result."""
    s = _random_stream(seed=11, n=250, n_types=5)
    kw = dict(t_low=0.1, t_high=2.0, threshold=12, max_level=3)
    base = mine(s, MinerConfig(**kw, engine="dense"))
    other = mine(s, MinerConfig(**kw, engine=engine,
                                cap_occ=24 * s.n_events, max_window=128))
    assert base.keys() == other.keys()
    for lvl in base:
        assert base[lvl].episodes == other[lvl].episodes, (engine, lvl)
        assert base[lvl].counts == other[lvl].counts, (engine, lvl)


def test_mine_arrays_consistent_with_mine():
    s = _random_stream(seed=5)
    cfg = MinerConfig(t_low=0.1, t_high=2.5, threshold=15, max_level=3)
    eps = mine(s, cfg)
    arrs = mine_arrays(s, cfg)
    assert eps.keys() == arrs.keys()
    for lvl in eps:
        assert [e.symbols for e in eps[lvl].episodes] == [
            tuple(int(x) for x in row) for row in arrs[lvl].symbols]
        assert eps[lvl].counts == [int(c) for c in arrs[lvl].counts]


def test_unknown_engine_raises():
    s = _random_stream(seed=2, n=50)
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=4, engine="nope")
    with pytest.raises(ValueError, match="engine must be one of"):
        mine(s, cfg)
