"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU with output-shape and finite-ness asserts, plus decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, applicable, get_config, input_specs, reduced
from repro.models import Model

ARCHS = sorted(REGISTRY)


def _batch(cfg, b=2, s=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    if cfg.frontend == "vision":
        s_text = s - cfg.n_patches
        return {
            "tokens": jax.random.randint(ks[0], (b, s_text), 0, cfg.vocab),
            "patches": jax.random.normal(ks[1], (b, cfg.n_patches, cfg.d_patch)),
            "targets": jax.random.randint(ks[2], (b, s_text), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "targets": jax.random.randint(ks[2], (b, s), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 1.5, \
        f"{arch}: init loss should be ~ln(V)"
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(1))
    cache = m.init_cache(2, 64)
    logits, cache = m.decode_step(params, cache, jnp.zeros((2,), jnp.int32),
                                  jnp.zeros((2,), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-3b", "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode must reproduce the teacher-forced forward logits."""
    cfg = reduced(get_config(arch))
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(2))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full_logits, _ = m.forward(params, {"tokens": tokens})
    cache = m.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = m.decode_step(params, cache, tokens[:, t],
                                  jnp.full((b,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=0.05, atol=0.05)


def test_chunked_xent_matches_full():
    cfg = reduced(get_config("granite-3-2b"))
    m_full = Model(cfg, remat="none", xent_chunk=8)
    params = m_full.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss_c, met = m_full.loss(params, batch)
    logits, aux = m_full.forward(params, batch)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None], -1)[..., 0]
    assert abs(float(met["ce"]) - float(jnp.mean(nll))) < 1e-4


def test_all_cells_have_input_specs():
    """Every (arch x applicable shape) cell is well-defined."""
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not applicable(cfg, shape):
                assert shape.name == "long_500k" and not cfg.sub_quadratic
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            n += 1
    assert n == 32  # 40 cells minus 8 documented long_500k skips


def test_n_params_reasonable():
    from repro.configs import _n_params
    # sanity: the 104B and 132B configs land near their names
    assert 90e9 < _n_params(get_config("command-r-plus-104b")) < 120e9
    assert 110e9 < _n_params(get_config("dbrx-132b")) < 150e9
    assert 0.4e9 < _n_params(get_config("qwen3-0.6b")) < 0.8e9
