"""Differential suite for the corpus miner.

``mine_corpus`` must equal the Python loop ``[mine_arrays(s) for s in
streams]`` bit-for-bit — per-level frequent sets, counts, candidate totals
and flag behavior — across engines and corpus sizes B in {1, 2, 32},
including duplicate-timestamp streams, all-padding (empty) streams, ragged
lengths, per-stream thresholds, and the golden fixture. The stream-sharded
path (mesh over the stream axis, no halo) runs in a subprocess with 8
simulated devices (tests/sharded_mining_child.py, mode ``corpus``).
"""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import strategies as sts
from repro.core import (MinerConfig, aggregate_min_streams, mine_arrays,
                        mine_corpus)
from repro.core.events import EventStream
from repro.core.mining import LevelArrays

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "data" / "golden_stream.npz"

ENGINES = ("dense", "dense_pallas_fused")


def _rand_stream(seed, n, n_types=5, rate=0.3):
    rng = np.random.default_rng(seed)
    return EventStream(
        rng.integers(0, n_types, n).astype(np.int32),
        np.cumsum(rng.exponential(rate, n)).astype(np.float32), n_types)


def _assert_levels_equal(base, got, ctx):
    assert base.keys() == got.keys(), (ctx, sorted(base), sorted(got))
    for lvl in base:
        np.testing.assert_array_equal(
            base[lvl].symbols, got[lvl].symbols, err_msg=f"{ctx} level {lvl}")
        np.testing.assert_array_equal(
            base[lvl].counts, got[lvl].counts, err_msg=f"{ctx} level {lvl}")
        assert base[lvl].n_candidates == got[lvl].n_candidates, (ctx, lvl)


def _assert_corpus_matches_loop(streams, cfg, thresholds=None, ctx=()):
    res = mine_corpus(streams, cfg, thresholds=thresholds)
    for i, stream in enumerate(streams):
        ref_cfg = cfg if thresholds is None else dataclasses.replace(
            cfg, threshold=thresholds[i])
        ref = mine_arrays(stream, ref_cfg)
        _assert_levels_equal(ref, res.per_stream[i], ctx + (i,))
    return res


# ---------------------------------------------------------------------------
# mine_corpus == per-stream loop: engines x B in {1, 2, 32}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize(
    "batch", (1, 2, pytest.param(32, marks=pytest.mark.slow)))
def test_mine_corpus_matches_loop(engine, batch):
    """Ragged corpus (duplicate timestamps, varied lengths): bit-for-bit
    parity with the per-stream loop."""
    rng = np.random.default_rng(batch * 101 + len(engine))
    streams = []
    for i in range(batch):
        n = int(rng.integers(1, 28 if batch == 32 else 90))
        streams.append(sts._random_stream(
            np.random.default_rng(1000 * batch + i), n, n_types=4, max_gap=4))
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=3, max_level=3,
                      engine=engine)
    _assert_corpus_matches_loop(streams, cfg, ctx=(engine, batch))


@pytest.mark.slow
@pytest.mark.parametrize("engine", ENGINES)
def test_mine_corpus_seeded_cases(engine):
    """The shared corpus case builder: all-padding streams every third
    seed, per-stream thresholds, ragged tails."""
    for seed in range(8):
        streams, t_high, thresholds = sts.make_corpus_case(seed)
        cfg = MinerConfig(t_low=0.0, t_high=t_high, threshold=1, max_level=3,
                          engine=engine)
        _assert_corpus_matches_loop(
            streams, cfg, thresholds=thresholds, ctx=(engine, seed))


@pytest.mark.slow
@pytest.mark.parametrize("engine", ("dense_pallas", "count_scan_write"))
def test_mine_corpus_other_engines_match_loop(engine):
    """Engines without any corpus-native method (per-level Pallas, faithful
    compaction) take the vmap fallback and still match their solo runs."""
    streams = [_rand_stream(i, n, n_types=4) for i, n in
               enumerate((45, 20, 33))]
    kw = dict(t_low=0.0, t_high=1.5, threshold=3, max_level=3, engine=engine)
    if engine == "count_scan_write":
        kw.update(cap_occ=16 * 45, max_window=128)
    _assert_corpus_matches_loop(streams, MinerConfig(**kw), ctx=(engine,))


def test_mine_corpus_union_chunking_preserves_parity():
    """Disjoint per-stream frontiers stack past cfg.max_candidates (a
    PER-STREAM valve): the union must be counted in chunks — bounding the
    device gather — without perturbing any stream's results."""
    rng = np.random.default_rng(7)
    streams = []
    for lo_t in (0, 4):                  # types 0-3 vs types 4-7: disjoint
        n = 80
        streams.append(EventStream(
            (rng.integers(0, 4, n) + lo_t).astype(np.int32),
            np.cumsum(rng.exponential(0.2, n)).astype(np.float32), 8))
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=3, max_level=3,
                      max_candidates=16)   # each stream's join = 16, union 32
    res = _assert_corpus_matches_loop(streams, cfg, ctx=("chunking",))
    assert res.per_stream[0][2].n_candidates == 16
    assert res.per_stream[1][2].n_candidates == 16


def test_mine_corpus_all_padding_and_duplicate_heavy():
    """An empty stream and an all-duplicate-timestamp stream ride along
    with normal ones; every stream still matches its solo run."""
    dup = EventStream(np.asarray([0, 1, 2, 1, 0], np.int32),
                      np.zeros(5, np.float32), 4)
    streams = [_rand_stream(0, 60, n_types=4),
               EventStream(np.zeros(0, np.int32), np.zeros(0, np.float32), 4),
               dup,
               _rand_stream(1, 33, n_types=4)]
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=2, max_level=3)
    _assert_corpus_matches_loop(streams, cfg, ctx=("padding",))


def test_mine_corpus_level_threshold_override():
    """A per-level threshold override is shared across streams and beats
    the per-stream base, exactly as mine_arrays resolves it."""
    streams = [_rand_stream(i, n) for i, n in enumerate((80, 50, 120))]
    thresholds = [4, 6, 3]
    cfg = MinerConfig(t_low=0.1, t_high=2.0, threshold=1,
                      level_thresholds={2: 9}, max_level=3)
    _assert_corpus_matches_loop(
        streams, cfg, thresholds=thresholds, ctx=("lvl-thr",))


def test_mine_corpus_engine_agreement():
    """dense and the fused corpus-native engine mine the same corpus to
    identical per-stream and aggregate results."""
    streams = [_rand_stream(i, n) for i, n in enumerate((70, 40, 90, 25))]
    kw = dict(t_low=0.0, t_high=1.8, threshold=4, max_level=3)
    base = mine_corpus(streams, MinerConfig(**kw, engine="dense"),
                       min_streams=2)
    other = mine_corpus(
        streams, MinerConfig(**kw, engine="dense_pallas_fused"),
        min_streams=2)
    for i in range(len(streams)):
        _assert_levels_equal(base.per_stream[i], other.per_stream[i], (i,))
    _assert_levels_equal(base.corpus, other.corpus, ("aggregate",))


# ---------------------------------------------------------------------------
# golden fixture, corpus variant
# ---------------------------------------------------------------------------


def test_mine_corpus_recovers_golden():
    """The golden stream mined as part of a mixed corpus (twice, alongside
    a random stream) reproduces the stored frequent sets bit-for-bit, and
    the >= 2-streams aggregate contains exactly the episodes the two golden
    copies agree on."""
    data = np.load(GOLDEN)
    golden = EventStream(data["types"], data["times"], int(data["n_types"]))
    noise = _rand_stream(9, 70, n_types=int(data["n_types"]))
    cfg = MinerConfig(
        t_low=float(data["t_low"]), t_high=float(data["t_high"]),
        threshold=int(data["threshold"]), max_level=int(data["max_level"]),
        max_candidates=int(data["max_candidates"]))
    res = mine_corpus([golden, noise, golden], cfg, min_streams=2)
    levels = [int(l) for l in data["levels"]]
    for s in (0, 2):
        assert sorted(res.per_stream[s]) == levels
        for lvl in levels:
            np.testing.assert_array_equal(
                res.per_stream[s][lvl].symbols, data[f"level{lvl}_symbols"])
            np.testing.assert_array_equal(
                res.per_stream[s][lvl].counts, data[f"level{lvl}_counts"])
            assert (res.per_stream[s][lvl].n_candidates
                    == int(data[f"level{lvl}_n_candidates"]))
    # every golden frequent episode is supported by >= 2 streams (the two
    # golden copies), so it must appear in the aggregate
    for lvl in levels:
        want = {tuple(int(x) for x in row)
                for row in data[f"level{lvl}_symbols"]}
        got = {tuple(int(x) for x in row)
               for row in res.corpus[lvl].symbols}
        assert want <= got, (lvl, want - got)


# ---------------------------------------------------------------------------
# >= m-streams aggregation semantics
# ---------------------------------------------------------------------------


def test_aggregate_min_streams_support_counts():
    def la(rows, counts, n):
        width = 1 if not rows else len(rows[0])
        return LevelArrays(np.asarray(rows, np.int32).reshape(-1, width),
                           np.asarray(counts, np.int32), n)
    per_stream = [
        {1: la([[0], [1]], [5, 9], 3), 2: la([[0, 1]], [4], 4)},
        {1: la([[1], [2]], [7, 2], 3), 2: la([[0, 1], [1, 2]], [3, 3], 4)},
        {1: la([[1]], [4], 3)},          # quiet after level 1
    ]
    agg = aggregate_min_streams(per_stream, 2)
    np.testing.assert_array_equal(agg[1].symbols, [[1]])
    np.testing.assert_array_equal(agg[1].counts, [3])   # support, not totals
    assert agg[1].n_candidates == 3                     # union size
    np.testing.assert_array_equal(agg[2].symbols, [[0, 1]])
    np.testing.assert_array_equal(agg[2].counts, [2])
    assert agg[2].n_candidates == 2
    # m=1 keeps the whole union in lexicographic row order
    agg1 = aggregate_min_streams(per_stream, 1)
    np.testing.assert_array_equal(agg1[1].symbols, [[0], [1], [2]])
    np.testing.assert_array_equal(agg1[1].counts, [1, 3, 1])


def test_aggregate_min_streams_validates():
    with pytest.raises(ValueError, match="min_streams"):
        aggregate_min_streams([], 0)


def test_mine_corpus_min_streams_from_config():
    streams = [_rand_stream(i, 50) for i in range(3)]
    cfg = MinerConfig(t_low=0.0, t_high=1.5, threshold=3, max_level=2,
                      min_streams=3)
    res = mine_corpus(streams, cfg)
    assert res.corpus is not None
    # every aggregate row is frequent in ALL streams here
    for lvl, agg in res.corpus.items():
        for row, support in zip(agg.symbols, agg.counts):
            assert support == 3
            for ps in res.per_stream:
                rows = {tuple(int(x) for x in r) for r in ps[lvl].symbols}
                assert tuple(int(x) for x in row) in rows


# ---------------------------------------------------------------------------
# validation + overflow masking
# ---------------------------------------------------------------------------


def test_mine_corpus_validates_inputs():
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1)
    with pytest.raises(ValueError, match="at least one"):
        mine_corpus([], cfg)
    mixed = [_rand_stream(0, 10, n_types=3), _rand_stream(1, 10, n_types=5)]
    with pytest.raises(ValueError, match="n_types"):
        mine_corpus(mixed, cfg)
    with pytest.raises(ValueError, match="thresholds"):
        mine_corpus([_rand_stream(0, 10)], cfg, thresholds=[1, 2])


def test_mine_corpus_overflow_raises_naming_stream():
    """cfg.cap smaller than one stream's per-type counts: the corpus run
    raises (naming the stream) exactly when that stream's solo run would."""
    big = _rand_stream(3, 120, n_types=2)    # ~60 events/type >> cap
    small = _rand_stream(4, 12, n_types=2)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=1, max_level=2, cap=16)
    with pytest.raises(RuntimeError, match="overflow"):
        mine_arrays(big, cfg)
    with pytest.raises(RuntimeError, match="stream 1"):
        mine_corpus([small, big], cfg)


def test_mine_corpus_quiet_stream_overflow_masked():
    """A stream that is quiet from level 1 (nothing frequent) never counts,
    so its capacity overflow must NOT poison the corpus — matching the
    per-stream loop, where its solo run breaks before counting."""
    big = _rand_stream(3, 120, n_types=2)
    small = _rand_stream(4, 12, n_types=2)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=1, max_level=2, cap=16)
    thresholds = [1, 10_000]                 # big goes quiet at level 1
    assert mine_arrays(
        big, dataclasses.replace(cfg, threshold=10_000)) is not None
    res = _assert_corpus_matches_loop(
        [small, big], cfg, thresholds=thresholds, ctx=("quiet-overflow",))
    assert list(res.per_stream[1]) == [1]    # level 1 only: quiet, masked


# ---------------------------------------------------------------------------
# kernel / dispatch layers
# ---------------------------------------------------------------------------


def test_ops_track_corpus_fold_parity():
    """ops.track_corpus (stream axis folded into the batch grid) is
    bit-for-bit the per-stream ops.track_batch stack."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    s, b, n, cap = 3, 4, 3, 24
    times = np.sort(
        np.cumsum(rng.exponential(0.4, (s, b, n, cap)), axis=-1), axis=-1
    ).astype(np.float32)
    # ragged: pad tails of some rows to +inf
    times[1, :, :, 17:] = np.inf
    times[2, 0, 1, :] = np.inf
    lo = np.zeros((b, n - 1), np.float32)
    hi = np.full((b, n - 1), 1.5, np.float32)
    starts, nsup, trunc = ops.track_corpus(
        times, lo, hi, block_next=8, block_prev=8)
    for i in range(s):
        st_i, ns_i, tr_i = ops.track_batch(
            times[i], lo, hi, block_next=8, block_prev=8)
        np.testing.assert_array_equal(np.asarray(starts[i]), np.asarray(st_i))
        np.testing.assert_array_equal(np.asarray(nsup[i]), np.asarray(ns_i))
        np.testing.assert_array_equal(np.asarray(trunc[i]), np.asarray(tr_i))


def test_track_corpus_dispatch_vmap_fallback_matches_native():
    """Engines without track_corpus fall back to a stream-axis vmap; the
    fused engine's native fold must agree with the dense fallback."""
    import jax.numpy as jnp
    from repro.core import tracking
    rng = np.random.default_rng(1)
    s, b, n, cap = 2, 3, 2, 16
    times = np.sort(
        np.cumsum(rng.exponential(0.5, (s, b, n, cap)), axis=-1), axis=-1
    ).astype(np.float32)
    lo = jnp.zeros((b, n - 1), jnp.float32)
    hi = jnp.full((b, n - 1), 2.0, jnp.float32)
    cfg = tracking.EngineConfig()
    dense = tracking.track_corpus_dispatch("dense", jnp.asarray(times), lo, hi, cfg)
    fused = tracking.track_corpus_dispatch(
        "dense_pallas_fused", jnp.asarray(times), lo, hi, cfg)
    assert dense.starts.shape == fused.starts.shape == (s, b, cap)
    np.testing.assert_array_equal(np.asarray(dense.valid), np.asarray(fused.valid))
    np.testing.assert_allclose(
        np.where(np.asarray(dense.valid), np.asarray(dense.starts), 0.0),
        np.where(np.asarray(fused.valid), np.asarray(fused.starts), 0.0))


def test_count_corpus_indexed_matches_count_batch_indexed():
    """The corpus counter's per-stream rows == the single-stream batched
    counter, engine by engine (same index, same candidates)."""
    import jax.numpy as jnp
    from repro.core import (count_batch_indexed, count_corpus_indexed,
                            type_index_batch)
    streams = [_rand_stream(i, n, n_types=4) for i, n in ((0, 40), (1, 25))]
    length = max(s.n_events for s in streams)
    types = np.full((2, length), -1, np.int32)
    times = np.full((2, length), np.inf, np.float32)
    for i, s in enumerate(streams):
        types[i, :s.n_events] = np.asarray(s.types)
        times[i, :s.n_events] = np.asarray(s.times)
    tables, counts = type_index_batch(types, times, 4, length)
    sym = jnp.asarray([[0, 1], [2, 3], [1, 1]], jnp.int32)
    lo = jnp.zeros((3, 1), jnp.float32)
    hi = jnp.full((3, 1), 2.0, jnp.float32)
    for engine in ENGINES:
        c, keep, ns, ovf = count_corpus_indexed(
            tables, counts, sym, lo, hi, jnp.asarray([2, 2], jnp.int32),
            engine=engine)
        for i in range(2):
            ci, nsi, ovfi = count_batch_indexed(
                tables[i], counts[i], sym, lo, hi, engine=engine)
            np.testing.assert_array_equal(np.asarray(c[i]), np.asarray(ci))
            np.testing.assert_array_equal(np.asarray(ns[i]), np.asarray(nsi))
            np.testing.assert_array_equal(np.asarray(ovf[i]), np.asarray(ovfi))
        np.testing.assert_array_equal(
            np.asarray(keep), np.asarray(c) >= 2)


# ---------------------------------------------------------------------------
# stream-sharded corpus (subprocess: 8 simulated devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_corpus_sharded_matches_loop_8dev():
    """slow-marked so the CI multidevice job (no -m filter) is its sole
    runner — the tests-matrix legs cover the single-device parity cells and
    already exercise shard_map itself via the sharded-mining smoke."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, str(REPO / "tests" / "sharded_mining_child.py"),
         "corpus", "--examples", "25"],
        env=env, capture_output=True, text=True, timeout=900, cwd=str(REPO))
    assert r.returncode == 0 and "OK corpus" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# benchmark harness regression: --only must reject unknown suite names
# ---------------------------------------------------------------------------


def test_bench_run_only_rejects_unknown_suite(monkeypatch, capsys):
    """`benchmarks/run.py --only typo` must be a loud usage error listing
    the valid suites — not a silent no-op a CI smoke step exits 0 on."""
    from benchmarks import run as bench_run
    monkeypatch.setattr(sys, "argv", ["run.py", "--only", "countign"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "countign" in err and "counting" in err


def test_bench_run_suite_name_validation():
    """Every real suite passes validation; typos and the empty name (a
    trailing comma) are caught."""
    from benchmarks import run as bench_run
    assert bench_run.unknown_suites(list(bench_run.SUITE_NAMES)) == []
    assert bench_run.unknown_suites(["counting", "countign"]) == ["countign"]
    assert bench_run.unknown_suites(["counting", ""]) == [""]


def test_bench_compare_best_entries_takes_per_cell_min():
    """The gate's noise retry keeps each (cell, engine)'s fastest entry
    across sweeps — a transient spike in one run cannot gate, a persistent
    regression (slow in both) still does."""
    from benchmarks import run as bench_run

    def e(us, engine="dense"):
        return {"engine": engine, "scheduler": "scan", "episode_len": 3,
                "n_events": 256, "batch": 4, "us_per_call": us}

    best = bench_run.best_entries([e(50.0), e(9.0, "fused")],
                                  [e(12.0), e(30.0, "fused")])
    by_engine = {b["engine"]: b["us_per_call"] for b in best}
    assert by_engine == {"dense": 12.0, "fused": 9.0}
    # persistent slowdown survives the retry and still regresses
    baseline = [e(10.0)]
    _, regressions = bench_run.compare_entries(
        baseline, bench_run.best_entries([e(40.0)], [e(41.0)]),
        threshold=0.25)
    assert regressions
