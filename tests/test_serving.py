"""Multi-tenant serving differential suite + eviction/churn edge cases.

The load-bearing property: every session served by a
``MiningSessionServer`` pool returns bit-for-bit what a standalone
``StreamingMiner`` fed the same chunks returns — across engines,
interleaving patterns (round-robin, bursty sessions that skip rounds,
random append order, coalesced multi-chunk rounds), per-session
thresholds, pool capacity growth mid-serve, and evict/re-create churn
into recycled slots. Plus the serving-specific contracts: eager append
validation, append-to-evicted raising, the session pool growing one
capacity class at a time (only the new bucket compiles), and the warm
protocol leaving zero plan-cache misses on live traffic.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import MinerConfig, MiningSessionServer, StreamingMiner
from repro.core import plan

ENGINES = ("dense", "dense_pallas", "dense_pallas_fused", "count_scan_write",
           "atomic_sort", "flags")


@pytest.fixture(autouse=True)
def fresh_cache():
    plan.reset_cache()
    plan.reset_trace_counts()
    yield
    plan.reset_cache()
    plan.reset_trace_counts()


def _cfg(engine="dense", **kw):
    base = dict(t_low=0.0, t_high=1.5, threshold=3, max_level=3,
                engine=engine, cap_occ=1024, max_window=64)
    base.update(kw)
    return MinerConfig(**base)


def _gen_chunks(rng, n_types, n_chunks, lo=3, hi=40):
    """One session's feed: time-sorted chunks with strictly growing spans."""
    t = 0.0
    out = []
    for _ in range(n_chunks):
        n = int(rng.integers(lo, hi))
        ty = rng.integers(0, n_types, n).astype(np.int32)
        dt = rng.random(n).astype(np.float64) * 0.7 + 0.01
        tm = t + np.cumsum(dt)
        t = float(tm[-1]) + float(rng.random()) * 0.5
        out.append((ty, tm.astype(np.float32)))
    return out


def _assert_levels_equal(got, want, ctx):
    assert set(got) == set(want), (ctx, sorted(got), sorted(want))
    for lvl in want:
        assert np.array_equal(got[lvl].symbols, want[lvl].symbols), (
            ctx, lvl, got[lvl].symbols, want[lvl].symbols)
        assert np.array_equal(got[lvl].counts, want[lvl].counts), (
            ctx, lvl, got[lvl].counts, want[lvl].counts)
        assert got[lvl].n_candidates == want[lvl].n_candidates, (ctx, lvl)


def _check_serving(engine, seed, *, n_sessions=4, n_chunks=3, n_types=5,
                   interleave="round_robin", initial_cap=32, thresholds=None,
                   max_sessions=2, **cfg_kw):
    """Serve ``n_sessions`` feeds and compare every session after every
    round against its solo ``StreamingMiner`` twin fed the same chunks."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(engine, **cfg_kw)
    feeds = [_gen_chunks(rng, n_types, n_chunks) for _ in range(n_sessions)]
    if thresholds is None:
        thresholds = [None] * n_sessions

    srv = MiningSessionServer(n_types, cfg, max_sessions=max_sessions,
                              initial_cap=initial_cap)
    sids = [srv.create_session(threshold=t) for t in thresholds]
    solos = [StreamingMiner(
        n_types,
        cfg if t is None else dataclasses.replace(cfg, threshold=t),
        initial_cap=initial_cap) for t in thresholds]

    for r in range(n_chunks):
        if interleave == "round_robin":
            order = list(range(n_sessions))
        elif interleave == "random":
            order = list(rng.permutation(n_sessions))
        elif interleave == "bursty":
            # each session appends only on ~2/3 of the rounds (never none)
            order = [s for s in range(n_sessions)
                     if (s + r) % 3 != 0 or n_chunks == 1]
        else:
            raise AssertionError(interleave)
        for s in order:
            srv.append(sids[s], *feeds[s][r])
            solos[s].append(*feeds[s][r])
        srv.flush()
        for s in range(n_sessions):
            _assert_levels_equal(srv.results(sids[s]), solos[s].results,
                                 (engine, seed, interleave, r, s))
    return srv, sids, solos, feeds, rng


@pytest.mark.parametrize("engine", ["dense", "dense_pallas_fused",
                                    "count_scan_write", "flags"])
def test_serving_matches_standalone(engine):
    _check_serving(engine, seed=0)


@pytest.mark.parametrize("interleave", ["random", "bursty"])
def test_serving_interleavings(interleave):
    _check_serving("dense", seed=1, n_chunks=4, interleave=interleave)


def test_serving_coalesces_multiple_appends_per_flush():
    """Several chunks queued between flushes absorb as one — and still
    match the solo miner that appended them one at a time (the streaming
    chunking-invariance property, inherited by the pool)."""
    rng = np.random.default_rng(2)
    cfg = _cfg()
    feeds = [_gen_chunks(rng, 5, 6) for _ in range(3)]
    srv = MiningSessionServer(5, cfg, max_sessions=4, initial_cap=32)
    sids = [srv.create_session() for _ in range(3)]
    solos = [StreamingMiner(5, cfg, initial_cap=32) for _ in range(3)]
    for half in (slice(0, 3), slice(3, 6)):
        for s in range(3):
            for ty, tm in feeds[s][half]:
                srv.append(sids[s], ty, tm)
                solos[s].append(ty, tm)
        srv.flush()
        for s in range(3):
            _assert_levels_equal(srv.results(sids[s]), solos[s].results,
                                 ("coalesce", half, s))


def test_serving_per_session_thresholds():
    _check_serving("dense", seed=3, thresholds=[2, 3, 5, None])


def test_serving_pool_cap_growth_mid_serve():
    # tiny initial cap: the per-type pool must grow (and re-bucket)
    # mid-serve without perturbing any session
    _check_serving("dense", seed=4, initial_cap=8, n_chunks=4)


def test_results_flushes_whole_pool():
    """Reading ONE session's results absorbs every session's pending
    chunks (one batched flush, not a private one)."""
    rng = np.random.default_rng(5)
    cfg = _cfg()
    feeds = [_gen_chunks(rng, 4, 1) for _ in range(2)]
    srv = MiningSessionServer(4, cfg, max_sessions=2)
    a, b = srv.create_session(), srv.create_session()
    srv.append(a, *feeds[0][0])
    srv.append(b, *feeds[1][0])
    srv.results(a)
    assert srv.pool.dirty_slots() == []
    solo = StreamingMiner(4, cfg)
    solo.append(*feeds[1][0])
    _assert_levels_equal(srv.results(b), solo.results, "flushed-by-peer")


def test_never_appended_session_matches_standalone():
    cfg = _cfg(threshold=1)
    srv = MiningSessionServer(4, cfg)
    sid = srv.create_session()
    _assert_levels_equal(srv.results(sid), StreamingMiner(4, cfg).results,
                         "never-appended")


def test_append_validation_is_eager():
    srv = MiningSessionServer(4, _cfg())
    sid = srv.create_session()
    with pytest.raises(ValueError, match="out of range"):
        srv.append(sid, [0, 9], [0.0, 1.0])
    with pytest.raises(ValueError, match="time-sorted"):
        srv.append(sid, [0, 1], [2.0, 1.0])
    # validation is against the last QUEUED event, not the last flushed one
    assert srv.append(sid, [0, 1], [0.0, 5.0]) == 2
    with pytest.raises(ValueError, match="time-sorted"):
        srv.append(sid, [2], [4.0])
    # all-padding chunks are accepted and absorb to nothing
    assert srv.append(sid, [-1, -1], [np.inf, np.inf]) == 0
    srv.flush()
    assert srv.pool.dirty_slots() == []


# -- eviction / churn edge cases --------------------------------------------


def test_evict_and_recreate_into_recycled_slot():
    """Churn: evict sessions mid-serve (pending chunks included), re-create
    into their recycled slots, keep serving — survivors unperturbed and the
    new tenants bit-for-bit fresh solo miners."""
    srv, sids, solos, feeds, rng = _check_serving(
        "dense", seed=6, n_sessions=4, n_chunks=2, max_sessions=4)
    n_types = 5

    # evict one mid-life and one with a PENDING chunk (discarded with it)
    srv.append(sids[1], [0, 1], [1e6, 1e6 + 1.0])
    for s in (1, 3):
        srv.evict(sids[s])
    assert len(srv) == 2
    assert sorted(srv.pool.live_slots()) == sorted(
        srv._slot_of[sids[s]] for s in (0, 2))

    new_feeds = [_gen_chunks(rng, n_types, 2) for _ in range(2)]
    new_sids = [srv.create_session() for _ in range(2)]
    assert srv.pool.n_slots == 4          # recycled, not grown
    new_solos = [StreamingMiner(n_types, _cfg(), initial_cap=32)
                 for _ in range(2)]
    for r in range(2):
        for j in range(2):
            srv.append(new_sids[j], *new_feeds[j][r])
            new_solos[j].append(*new_feeds[j][r])
        srv.flush()
        for j in range(2):
            _assert_levels_equal(srv.results(new_sids[j]),
                                 new_solos[j].results, ("recycled", r, j))
        for s in (0, 2):                   # survivors keep their results
            _assert_levels_equal(srv.results(sids[s]), solos[s].results,
                                 ("survivor", r, s))


def test_append_to_evicted_session_raises():
    srv = MiningSessionServer(3, _cfg())
    sid = srv.create_session()
    srv.evict(sid)
    with pytest.raises(KeyError, match="evicted"):
        srv.append(sid, [0], [1.0])
    with pytest.raises(KeyError, match="evicted"):
        srv.results(sid)
    with pytest.raises(KeyError):
        srv.evict(sid)
    # a NEW session gets a fresh id even when it reuses the slot
    sid2 = srv.create_session()
    assert sid2 != sid
    with pytest.raises(KeyError, match="evicted"):
        srv.append(sid, [0], [1.0])


def test_all_sessions_evicted_pool_keeps_serving():
    rng = np.random.default_rng(7)
    cfg = _cfg()
    srv = MiningSessionServer(4, cfg, max_sessions=2)
    sids = [srv.create_session() for _ in range(2)]
    for sid in sids:
        srv.append(sid, *_gen_chunks(rng, 4, 1)[0])
    srv.flush()
    for sid in sids:
        srv.evict(sid)
    assert len(srv) == 0 and srv.pool.live_slots() == []
    srv.flush()                            # empty pool: a no-op
    feed = _gen_chunks(rng, 4, 2)
    sid = srv.create_session()
    solo = StreamingMiner(4, cfg)
    for ty, tm in feed:
        srv.append(sid, ty, tm)
        solo.append(ty, tm)
        _assert_levels_equal(srv.results(sid), solo.results, "after-wipe")


def test_slot_boundary_growth_compiles_only_new_bucket():
    """Crossing the session-axis capacity class re-buckets the pool:
    exactly the streams=4 plans compile, every streams=2 plan stays
    cached (hit, not re-compiled)."""
    rng = np.random.default_rng(8)
    cfg = _cfg(threshold=2)
    srv = MiningSessionServer(4, cfg, max_sessions=2, initial_cap=64)
    feeds = [_gen_chunks(rng, 4, 2) for _ in range(3)]
    sids = [srv.create_session() for _ in range(2)]
    for r in range(2):
        for s in range(2):
            srv.append(sids[s], *feeds[s][r])
        srv.flush()
    before = set(plan.cached_plans())
    assert before and all(p.streams == 2 for p in before)

    sids.append(srv.create_session())      # 2 -> 4: one new capacity class
    assert srv.pool.n_slots == 4
    for r in range(2):
        srv.append(sids[2], *feeds[2][r])
        srv.flush()
    after = plan.cached_plans()
    new = [p for p in after if p not in before]
    assert new and all(p.streams == 4 for p in new)
    assert all(p.fn == "count_corpus_tail_grouped" for p in after)

    # and the grown pool still serves correct results
    solo = StreamingMiner(4, cfg, initial_cap=64)
    for r in range(2):
        solo.append(*feeds[2][r])
    _assert_levels_equal(srv.results(sids[2]), solo.results, "post-growth")


def test_warm_serving_has_zero_plan_cache_misses():
    """The serving-startup gate: after ``warm()`` at the pool's capacity
    classes, live traffic that stays inside them never compiles — and
    never even misses the plan cache."""
    rng = np.random.default_rng(9)
    cfg = _cfg(threshold=2)
    srv = MiningSessionServer(4, cfg, max_sessions=8, initial_cap=64)
    report = srv.warm(batches=[16, 32, 64], tail_caps=[16, 32])
    assert report["compiled"] == len(srv.plans(batches=[16, 32, 64],
                                               tail_caps=[16, 32]))
    base = plan.cache_stats()["misses"]
    feeds = [_gen_chunks(rng, 4, 2) for _ in range(5)]
    sids = [srv.create_session() for _ in range(5)]
    for r in range(2):
        for s in range(5):
            srv.append(sids[s], *feeds[s][r])
        srv.flush()
    for sid in sids:
        srv.results(sid)
    assert plan.cache_stats()["misses"] == base


def test_grouped_kernel_matches_union_kernel():
    """`count_corpus_tail_grouped` is `count_corpus_tail_indexed` with the
    key->session pairing made explicit: feeding each session the shared
    union rows in a per-session permutation must reproduce the union
    grid's cells exactly (counts, carries, and overflow/short flags)."""
    from repro.core import (count_corpus_tail_grouped,
                            count_corpus_tail_indexed)

    rng = np.random.default_rng(11)
    s, n_types, cap, b, level, tail = 5, 6, 32, 7, 3, 8
    tables = np.full((s, n_types, cap), np.inf, np.float32)
    counts = np.zeros((s, n_types), np.int32)
    for i in range(s):
        for t in range(n_types):
            n = int(rng.integers(0, cap - 4))
            tables[i, t, :n] = np.sort(rng.random(n).astype(np.float32) * 9)
            counts[i, t] = n
    old_counts = (counts * rng.random((s, n_types))).astype(np.int32)
    t0 = rng.random(s).astype(np.float32) * 9
    sym = rng.integers(0, n_types, (b, level)).astype(np.int32)
    lo = np.full((b, level - 1), 0.0, np.float32)
    hi = np.full((b, level - 1), 2.0, np.float32)
    pe = np.where(rng.random((s, b)) < 0.5, -np.inf,
                  rng.random((s, b)) * 5).astype(np.float32)
    pc = rng.integers(0, 4, (s, b)).astype(np.int32)

    ref = [np.asarray(a) for a in count_corpus_tail_indexed(
        tables, counts, old_counts, t0, sym, lo, hi, pe, pc,
        tail_cap=tail, engine="dense", cap_occ=256)]
    perms = np.stack([rng.permutation(b) for _ in range(s)])
    sym_g = sym[perms]                                      # [S, B, N]
    pe_g = np.take_along_axis(pe, perms, axis=1)
    pc_g = np.take_along_axis(pc, perms, axis=1)
    got = [np.asarray(a) for a in count_corpus_tail_grouped(
        tables, counts, old_counts, t0, sym_g, lo, hi, pe_g, pc_g,
        tail_cap=tail, engine="dense", cap_occ=256)]
    for r, g in zip(ref, got):
        assert np.array_equal(np.take_along_axis(r, perms, axis=1), g)


def test_serving_rejects_mesh():
    with pytest.raises(ValueError, match="single-device"):
        MiningSessionServer(4, _cfg(mesh=object()))


@pytest.mark.slow
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("interleave", ["round_robin", "random", "bursty"])
def test_serving_sweep(engine, seed, interleave):
    _check_serving(engine, seed, n_sessions=6, n_chunks=4,
                   interleave=interleave, max_sessions=2)
