"""Multi-device behaviour (shard_map mining, dry-run machinery, sharded MoE)
via subprocesses with forced host-device counts — jax locks the device count
at first init, so these cannot run in the main pytest process."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def _run(code: str, devices: int, timeout=420):
    env = dict(ENV)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_sharded_counting_exact_8dev():
    code = """
import numpy as np, jax
from repro.core import serial, shard_stream, count_fsm_numpy
from repro.core.distributed import make_count_sharded_jit
rng = np.random.default_rng(5)
n = 600
times = np.cumsum(rng.exponential(0.4, size=n)).astype(np.float32)
types = rng.integers(0, 5, size=n).astype(np.int32)
ep = serial([1, 2, 3], 0.1, 2.5)
want = count_fsm_numpy(types, times, ep)
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
ty, tm = shard_stream(types, times, 4)
got, short, overflow = make_count_sharded_jit(ep, mesh, n_types=5, halo=150)(ty, tm)
assert int(got) == want, (int(got), want)
assert not bool(short) and not bool(overflow)
print("OK")
"""
    r = _run(code, 8)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a reduced config + tiny mesh."""
    env = dict(ENV, REPRO_DRYRUN_DEVICES="8")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "train_4k", "--reduced", "--mesh-shape", "2,4",
         "--out", "/tmp/test_dryrun_cell"],
        env=env, capture_output=True, text=True, timeout=420, cwd=str(REPO))
    assert "DONE ok=1" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_compressed_psum_8dev():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("pod",))
x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)), jnp.float32)
def f(x):
    key = jax.random.fold_in(jax.random.PRNGKey(0), jax.lax.axis_index("pod"))
    return compressed_psum(x[0], "pod", key)[None]
from repro.compat import shard_map
y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))(x)
true = jnp.sum(x, axis=0)
rel = float(jnp.linalg.norm(y[0] - true) / jnp.linalg.norm(true))
assert rel < 0.05, rel
print("OK")
"""
    r = _run(code, 8)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_pipeline_parallel_4stage():
    """4-stage looped pipeline == sequential layer application."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 6, 3, 8
ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
stage_fn = lambda w, h: jnp.tanh(h @ w)
got = jax.jit(lambda ws, x: pipeline_forward(stage_fn, ws, x, mesh))(ws, x)
want = x
for s in range(n_stages):
    want = jnp.tanh(want @ ws[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
print("OK")
"""
    r = _run(code, 4)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launch_train_reduced_with_compression():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "stablelm-1.6b",
         "--reduced", "--steps", "8", "--batch", "2", "--seq-len", "32",
         "--compress-grads", "--ckpt-dir", "/tmp/test_launch_train"],
        env=ENV, capture_output=True, text=True, timeout=420, cwd=str(REPO))
    assert "done: steps" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launch_serve_continuous_batching():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-0.6b",
         "--requests", "5", "--max-new", "8", "--batch", "3"],
        env=ENV, capture_output=True, text=True, timeout=420, cwd=str(REPO))
    assert "served 5 requests" in r.stdout, r.stdout + r.stderr
