"""Streaming miner differential suite + the PR's bugfix regressions.

The load-bearing property: ``StreamingMiner.append`` after any chunking of a
stream — per-event chunks, empty chunks, all-padding chunks, duplicate
timestamps at chunk boundaries, capacity growth mid-stream — returns
bit-for-bit what a cold ``mine_arrays`` returns for the concatenated
stream, for every registered engine and both schedulers. Plus unit tests
for the pieces (incremental index, greedy chain-state carry, ``t_min`` seed
restriction) and regressions for the ``cap=0`` falsy-default bug and the
batch-level negative-padding remap.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from repro.core import (EventStream, MinerConfig, StreamingMiner,
                        count_nonoverlapped, count_occurrences, grow_type_index,
                        mine_arrays, mine_corpus, serial, type_index,
                        type_index_batch, type_index_update)
from repro.core import scheduling, tracking
from repro.core.events import episode_symbol_times

ENGINES = ("dense", "dense_pallas", "dense_pallas_fused", "count_scan_write",
           "atomic_sort", "flags")


def _random_chunks(rng, n):
    """Random chunk sizes covering n events, with empty chunks mixed in."""
    sizes = []
    left = n
    while left > 0:
        sz = int(rng.integers(0, min(left, 40) + 1))
        sizes.append(sz)
        left -= sz
    if not sizes:
        sizes = [0]
    return sizes


def _assert_levels_equal(got, want, ctx):
    assert set(got) == set(want), (ctx, sorted(got), sorted(want))
    for lvl in want:
        assert np.array_equal(got[lvl].symbols, want[lvl].symbols), (
            ctx, lvl, got[lvl].symbols, want[lvl].symbols)
        assert np.array_equal(got[lvl].counts, want[lvl].counts), (
            ctx, lvl, got[lvl].counts, want[lvl].counts)
        assert got[lvl].n_candidates == want[lvl].n_candidates, (ctx, lvl)


def _check_streaming(seed, engine, parallel=False, n=100, n_types=3,
                     check_prefixes=False, initial_cap=None, **cfg_kw):
    rng = np.random.default_rng(seed)
    s = strategies._random_stream(rng, n, n_types, max_gap=2)
    types, times = np.asarray(s.types), np.asarray(s.times)
    kw = dict(t_low=0.0, t_high=1.0, threshold=4, max_level=3, engine=engine,
              parallel_schedule=parallel, cap_occ=4 * n, max_window=64)
    kw.update(cfg_kw)
    cfg = MinerConfig(**kw)
    miner = StreamingMiner(n_types, cfg, initial_cap=initial_cap)
    i = 0
    res = None
    for sz in _random_chunks(rng, n):
        res = miner.append(types[i:i + sz], times[i:i + sz])
        i += sz
        if check_prefixes:
            cold = mine_arrays(EventStream(types[:i], times[:i], n_types), cfg)
            _assert_levels_equal(res, cold, (seed, engine, parallel, i))
    assert i == n
    cold = mine_arrays(EventStream(types, times, n_types), cfg)
    _assert_levels_equal(res, cold, (seed, engine, parallel, "final"))


# ---------------------------------------------------------------------------
# incremental index
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_type_index_update_matches_cold(seed):
    """Chunked scatters + geometric growth == one cold type_index build."""
    rng = np.random.default_rng(seed)
    n, n_types = int(rng.integers(1, 200)), 4
    s = strategies._random_stream(rng, n, n_types, max_gap=1)
    types, times = np.asarray(s.types), np.asarray(s.times)
    cap = 4
    table = jnp.full((n_types, cap), jnp.inf, jnp.float32)
    counts = jnp.zeros((n_types,), jnp.int32)
    i = 0
    for sz in _random_chunks(rng, n):
        chunk_ty, chunk_tm = types[i:i + sz], times[i:i + sz]
        i += sz
        need = int((np.asarray(counts)
                    + np.bincount(chunk_ty, minlength=n_types)).max())
        while need > cap:
            cap *= 2
            table = grow_type_index(table, cap)
        table, counts = type_index_update(table, counts, chunk_ty, chunk_tm)
        want_t, want_c = type_index(types[:i], times[:i], n_types, cap)
        assert np.array_equal(np.asarray(want_c), np.asarray(counts)), (seed, i)
        assert np.array_equal(np.asarray(want_t), np.asarray(table)), (seed, i)


def test_type_index_update_drops_negative_padding():
    """-1 chunk padding must not corrupt the LAST type's row (scatter wrap)."""
    n_types = 3
    table = jnp.full((n_types, 4), jnp.inf, jnp.float32)
    counts = jnp.zeros((n_types,), jnp.int32)
    table, counts = type_index_update(
        table, counts,
        np.array([2, -1, 2, -1], np.int32),
        np.array([1.0, np.inf, 2.0, np.inf], np.float32))
    assert np.array_equal(np.asarray(counts), [0, 0, 2])
    assert np.allclose(np.asarray(table)[2, :2], [1.0, 2.0])
    assert np.all(np.isinf(np.asarray(table)[:2]))


def test_grow_type_index_contract():
    table = jnp.asarray([[1.0, 2.0]], jnp.float32)
    grown = grow_type_index(table, 4)
    assert grown.shape == (1, 4)
    assert np.allclose(np.asarray(grown)[0, :2], [1.0, 2.0])
    assert np.all(np.isinf(np.asarray(grown)[0, 2:]))
    assert grow_type_index(table, 2) is table
    with pytest.raises(ValueError):
        grow_type_index(table, 1)


# ---------------------------------------------------------------------------
# greedy chain-state carry
# ---------------------------------------------------------------------------


def _intervals(rng, m):
    ends = np.sort(rng.uniform(0, 20, m)).astype(np.float32)
    starts = (ends - rng.uniform(0.1, 3.0, m)).astype(np.float32)
    valid = rng.random(m) < 0.85
    return tracking.Occurrences(
        jnp.where(jnp.asarray(valid), jnp.asarray(starts), -jnp.inf),
        jnp.where(jnp.asarray(valid), jnp.asarray(ends), jnp.inf),
        jnp.asarray(valid), jnp.int32(0), jnp.bool_(False))


@pytest.mark.parametrize("parallel", (False, True))
@pytest.mark.parametrize("seed", range(8))
def test_greedy_state_stitch_equals_whole(seed, parallel):
    """fold(fold(s0, prefix), suffix) == fold(s0, whole) for both schedulers,
    and scan/binary-lifting agree on every intermediate state."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 60))
    occ = _intervals(rng, m)
    cut = int(rng.integers(0, m + 1))

    def part(lo, hi):
        return tracking.Occurrences(
            occ.starts[lo:hi], occ.ends[lo:hi], occ.valid[lo:hi],
            jnp.int32(0), jnp.bool_(False))

    whole = int(scheduling.greedy_count(occ, parallel=parallel))
    pe, pc = scheduling.greedy_state(
        part(0, cut), -jnp.inf, jnp.int32(0), parallel=parallel)
    pe2, pc2 = scheduling.greedy_state(part(cut, m), pe, pc, parallel=parallel)
    assert int(pc2) == whole, (seed, parallel, cut)
    # scan and lifting must agree on the carried state itself, not just the
    # final count — the streaming cache stores it across appends
    se, sc = scheduling.greedy_scan_state(part(0, cut), -jnp.inf, jnp.int32(0))
    le, lc = scheduling.greedy_parallel_state(part(0, cut), -jnp.inf,
                                              jnp.int32(0))
    assert int(sc) == int(lc)
    assert float(se) == float(le)


def test_greedy_state_strict_tie():
    """An interval starting exactly at the carried prev_end is NOT taken."""
    occ = tracking.Occurrences(
        jnp.asarray([1.0, 2.5], jnp.float32), jnp.asarray([2.0, 3.0]),
        jnp.asarray([True, True]), jnp.int32(0), jnp.bool_(False))
    for parallel in (False, True):
        pe, pc = scheduling.greedy_state(
            occ, jnp.float32(1.0), jnp.int32(5), parallel=parallel)
        # start 1.0 == prev_end 1.0 -> skipped; start 2.5 > 1.0 -> taken
        assert int(pc) == 6 and float(pe) == 3.0, parallel


# ---------------------------------------------------------------------------
# t_min seed restriction (the tail view's correctness guard)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_t_min_equals_truncated_stream(engine):
    """count_occurrences(t_min=T) == a cold count of the suffix stream."""
    rng = np.random.default_rng(3)
    for seed in range(4):
        s = strategies._random_stream(
            np.random.default_rng(seed), 60, 3, max_gap=2)
        ep = serial([0, 1, 0], 0.0, 1.5)
        cut = float(np.asarray(s.times)[int(rng.integers(0, 60))])
        table, counts = type_index(s.types, s.times, s.n_types, s.n_events)
        sym, lo, hi = ep.as_arrays()
        tbs, _ = episode_symbol_times(table, counts, sym)
        got = count_occurrences(
            tbs, lo, hi, engine=engine, cap_occ=4 * s.n_events,
            max_window=64, t_min=cut)
        keep = np.asarray(s.times) >= cut
        trunc = EventStream(np.asarray(s.types)[keep],
                            np.asarray(s.times)[keep], s.n_types)
        want = count_nonoverlapped(trunc, ep, engine=engine,
                                   cap_occ=4 * s.n_events, max_window=64)
        assert int(got.count) == int(want.count), (engine, seed, cut)


# ---------------------------------------------------------------------------
# streaming == cold, across engines x chunkings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_streaming_matches_cold_dense_prefixes(seed):
    """Every prefix (not just the final stream) matches the cold miner."""
    _check_streaming(seed, "dense", check_prefixes=True, n=90)


@pytest.mark.parametrize("parallel", (False, True))
def test_streaming_matches_cold_dense_schedulers(parallel):
    _check_streaming(11, "dense", parallel=parallel, n=120)


@pytest.mark.parametrize("engine", ("dense", "count_scan_write"))
def test_streaming_matches_cold_fast_engines(engine):
    for seed in range(3):
        _check_streaming(seed + 20, engine, n=80)


@pytest.mark.slow
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("parallel", (False, True))
def test_streaming_matches_cold_all_engines(engine, parallel):
    for seed in range(3):
        _check_streaming(seed + 40, engine, parallel=parallel, n=110,
                         check_prefixes=(engine == "dense"))


def test_streaming_per_event_chunks_and_duplicates():
    """Worst-case chunking: one event per append on a duplicate-heavy
    stream (zero gaps with p=1/2 -> boundary ties at almost every append)."""
    rng = np.random.default_rng(5)
    n, n_types = 40, 2
    s = strategies._random_stream(rng, n, n_types, max_gap=1)
    types, times = np.asarray(s.types), np.asarray(s.times)
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=2, max_level=3)
    miner = StreamingMiner(n_types, cfg)
    for i in range(n):
        res = miner.append(types[i:i + 1], times[i:i + 1])
        cold = mine_arrays(EventStream(types[:i + 1], times[:i + 1], n_types),
                           cfg)
        _assert_levels_equal(res, cold, i)


def test_streaming_duplicate_timestamps_at_boundary():
    """The chunk's first events share their timestamp with the old stream's
    last — the old occurrence is cached history, the new one is delta."""
    ty = np.array([0, 1, 0, 1, 0, 1, 0, 1], np.int32)
    tm = np.array([0, 0, 0, 0.5, 0.5, 0.5, 0.5, 1.0], np.float32)
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_level=2)
    miner = StreamingMiner(2, cfg)
    miner.append(ty[:4], tm[:4])
    res = miner.append(ty[4:], tm[4:])
    cold = mine_arrays(EventStream(ty, tm, 2), cfg)
    _assert_levels_equal(res, cold, "dup-boundary")


def test_streaming_all_padding_and_empty_chunks():
    """-1/+inf padding chunks are dropped; results stay the last real ones."""
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1, max_level=2)
    miner = StreamingMiner(2, cfg)
    # empty + all-padding before ANY real event: the empty-stream result
    empty = miner.append(np.array([-1, -1], np.int32),
                         np.array([np.inf, np.inf], np.float32))
    assert set(empty) == {1} and empty[1].symbols.shape[0] == 0
    miner.append(np.array([0, 1], np.int32), np.array([0.0, 0.5], np.float32))
    res1 = dict(miner.results)
    res2 = miner.append(np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    _assert_levels_equal(res2, res1, "empty chunk")
    # padding mixed INTO a real chunk is stripped before indexing
    res3 = miner.append(np.array([-1, 1, -1], np.int32),
                        np.array([np.inf, 1.0, np.inf], np.float32))
    cold = mine_arrays(
        EventStream(np.array([0, 1, 1], np.int32),
                    np.array([0.0, 0.5, 1.0], np.float32), 2), cfg)
    _assert_levels_equal(res3, cold, "padding in chunk")


def test_streaming_capacity_growth_mid_stream():
    """initial_cap=2 forces repeated geometric growth; results unaffected."""
    _check_streaming(31, "dense", n=120, check_prefixes=True, initial_cap=2)


def test_streaming_large_magnitude_times():
    """The suffix-cutoff slack must be absolute at the STREAM's magnitude:
    at t ~ 1.6e5 a float32 ulp is ~0.016, far larger than any relative
    slack at t0's own magnitude — a too-tight cutoff silently drops seeds."""
    rng = np.random.default_rng(7)
    base = np.float32(1.6e5)
    gaps = rng.integers(0, 3, 160).astype(np.float32) * 0.25
    times = (base + np.cumsum(gaps)).astype(np.float32)
    types = rng.integers(0, 3, 160).astype(np.int32)
    cfg = MinerConfig(t_low=0.0, t_high=2.0, threshold=4, max_level=3)
    miner = StreamingMiner(3, cfg)
    i = 0
    for sz in (40, 40, 40, 40):
        res = miner.append(types[i:i + sz], times[i:i + sz])
        i += sz
        cold = mine_arrays(EventStream(types[:i], times[:i], 3), cfg)
        _assert_levels_equal(res, cold, ("large-magnitude", i))


def test_streaming_cache_stays_bounded():
    """Chain states not advanced through the latest append are evicted, so
    the cache tracks the LIVE candidate sets, not every candidate ever."""
    rng = np.random.default_rng(13)
    s = strategies._random_stream(rng, 120, 3, max_gap=2)
    types, times = np.asarray(s.types), np.asarray(s.times)
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=4, max_level=3)
    miner = StreamingMiner(3, cfg)
    i = 0
    for sz in (30, 30, 30, 30):
        miner.append(types[i:i + sz], times[i:i + sz])
        i += sz
        for level, cache in miner._cache.items():
            assert all(st.seq == miner.seq for st in cache.values()), level


def test_streaming_newly_frequent_triggers_backfill():
    """A type crosses threshold late -> its episodes backfill over the whole
    history (count includes occurrences from before it became frequent)."""
    # type 1 appears once early (infrequent), then floods in chunk 2; the
    # pair 0->1 from the early events must be included in the final count
    ty1 = np.array([0, 1, 0, 0, 0], np.int32)
    tm1 = np.array([0.0, 0.5, 1.0, 2.0, 3.0], np.float32)
    ty2 = np.array([1, 0, 1, 0, 1], np.int32)
    tm2 = np.array([3.5, 4.0, 4.5, 5.0, 5.5], np.float32)
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=3, max_level=2)
    miner = StreamingMiner(2, cfg)
    r1 = miner.append(ty1, tm1)
    assert 2 not in r1 or not any(
        (row == [0, 1]).all() for row in r1[2].symbols)
    r2 = miner.append(ty2, tm2)
    cold = mine_arrays(
        EventStream(np.concatenate([ty1, ty2]), np.concatenate([tm1, tm2]), 2),
        cfg)
    _assert_levels_equal(r2, cold, "late-frequent backfill")


def test_streaming_rejections():
    cfg = MinerConfig(t_low=0.0, t_high=1.0, threshold=1)
    miner = StreamingMiner(2, cfg)
    miner.append([0, 1], [0.0, 1.0])
    with pytest.raises(ValueError, match="time-sorted"):
        miner.append([0], [0.5])                       # before last append
    with pytest.raises(ValueError, match="time-sorted"):
        miner.append([0, 1], [3.0, 2.0])               # unsorted chunk
    with pytest.raises(ValueError, match="out of range"):
        miner.append([2], [4.0])
    with pytest.raises(ValueError, match="growth"):
        StreamingMiner(2, cfg, growth=1.0)
    import jax
    from jax.sharding import Mesh
    mesh_cfg = dataclasses.replace(
        cfg, mesh=Mesh(np.array(jax.devices()[:1]), ("data",)))
    with pytest.raises(ValueError, match="single-device"):
        StreamingMiner(2, mesh_cfg)


@pytest.mark.slow
def test_streaming_seeded_sweep():
    """Wider seeded sweep (the adversarial stream generators of
    tests/strategies.py: zero-gap duplicates, ragged chunks)."""
    for seed in range(12):
        _check_streaming(seed + 100, "dense", n=140, check_prefixes=True)


# ---------------------------------------------------------------------------
# regression: explicit cap=0 / falsy knobs are honored, not "unset"
# ---------------------------------------------------------------------------


def _tiny_stream():
    return EventStream(np.array([0, 1, 0], np.int32),
                       np.array([0.0, 0.5, 1.0], np.float32), 2)


def test_cap_zero_is_not_unset():
    """cap=0 used to silently mean cap=n_events; now it is rejected."""
    ep = serial([0, 1], 0.0, 1.0)
    with pytest.raises(ValueError, match="cap"):
        count_nonoverlapped(_tiny_stream(), ep, cap=0)
    with pytest.raises(ValueError, match="cap"):
        mine_arrays(_tiny_stream(),
                    MinerConfig(t_low=0.0, t_high=1.0, threshold=1, cap=0))
    with pytest.raises(ValueError, match="cap"):
        mine_corpus([_tiny_stream()],
                    MinerConfig(t_low=0.0, t_high=1.0, threshold=1, cap=0))


def test_cap_one_is_honored_with_overflow():
    """A tiny explicit cap must clip (and flag), not widen to n_events."""
    ep = serial([0, 1], 0.0, 1.0)
    res = count_nonoverlapped(_tiny_stream(), ep, cap=1)
    assert bool(res.overflow)        # type 0 has 2 events > cap
    full = count_nonoverlapped(_tiny_stream(), ep)
    assert not bool(full.overflow)


def test_cap_occ_zero_is_not_unset():
    """cap_occ=0 used to silently widen to cap for the faithful engines."""
    ep = serial([0, 1], 0.0, 1.0)
    table, counts = type_index(_tiny_stream().types, _tiny_stream().times,
                               2, 3)
    sym, lo, hi = ep.as_arrays()
    tbs, _ = episode_symbol_times(table, counts, sym)
    with pytest.raises(ValueError, match="cap_occ"):
        count_occurrences(tbs, lo, hi, engine="count_scan_write", cap_occ=0)


# ---------------------------------------------------------------------------
# regression: batch-level negative-padding remap (PR 3's fix, corpus surface)
# ---------------------------------------------------------------------------


def test_type_index_batch_negative_padding_remap():
    """The vmapped corpus index must drop -1 padding exactly like the
    single-stream path: a raw -1 would wrap into the LAST type's row,
    inflating its count and racing +inf writes into its table."""
    n_types, cap = 3, 4
    types = np.array([[0, 2, -1, -1],        # padded tail
                      [-1, -1, -1, -1],      # all-padding stream
                      [2, 2, 2, -1]], np.int32)
    times = np.array([[0.0, 1.0, np.inf, np.inf],
                      [np.inf] * 4,
                      [0.5, 0.5, 2.0, np.inf]], np.float32)
    tables, counts = type_index_batch(types, times, n_types, cap)
    tables, counts = np.asarray(tables), np.asarray(counts)
    # last type's counts are exact — padding contributed nothing
    assert np.array_equal(counts, [[1, 0, 1], [0, 0, 0], [0, 0, 3]])
    # and its rows hold only real times (no +inf raced into a live slot)
    assert np.allclose(tables[0, 2, :1], [1.0])
    assert np.all(np.isinf(tables[1]))
    assert np.allclose(tables[2, 2, :3], [0.5, 0.5, 2.0])
    # row-for-row identical to the single-stream index of the real events
    for s in range(3):
        keep = types[s] >= 0
        want_t, want_c = type_index(types[s][keep], times[s][keep],
                                    n_types, cap)
        assert np.array_equal(np.asarray(want_t), tables[s]), s
        assert np.array_equal(np.asarray(want_c), counts[s]), s
