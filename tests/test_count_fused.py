"""Single-launch count pipeline: kernel vs host-greedy oracle vs FSM.

The fused engine's ``count_batch`` contract (ISSUE 6): tracking, §IV-D
compaction, and the greedy non-overlap fold all run inside ONE kernel
launch, and the results — counts, carried ``(prev_end, count)`` state,
``n_superset`` — are bit-for-bit identical to every track-then-schedule
engine under BOTH scheduler flags. The carry parity is what keeps the
streaming miner's chain-state stitching exact on the fused path.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import compaction, count_fsm_numpy, serial, tracking
from repro.core.counting import (
    count_batch_dispatch,
    count_batch_indexed,
    count_batch_indexed_stateful,
)
from repro.core.events import EventStream, type_index

CAP = 128   # fixed capacity so seeded examples share compilations

ENGINES = ("dense", "dense_pallas", "dense_pallas_fused")


def _batch_times(rng, b, n, cap, empty_rows=()):
    times = np.full((b, n, cap), np.inf, np.float32)
    for i in range(b):
        for s in range(n):
            if (i, s) in empty_rows:
                continue
            n_real = int(rng.integers(0, cap + 1))
            times[i, s, :n_real] = np.sort(
                rng.uniform(0, 100, n_real)).astype(np.float32)
    return times


def _random_case(seed, n_types=4, batch=4):
    """One seeded (stream, equal-length episode batch) parity case."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 120))
    times = np.cumsum(rng.integers(0, 6, n).astype(np.float32) * 0.25)
    types = rng.integers(0, n_types, n).astype(np.int32)
    stream = EventStream(types, times.astype(np.float32), n_types)
    ep_len = int(rng.integers(2, 5))
    lo = float(rng.uniform(0, 1))
    hi = lo + float(rng.uniform(0.3, 4))
    episodes = [serial(rng.integers(0, n_types, ep_len).tolist(), lo, hi)
                for _ in range(batch)]
    return stream, episodes


def _indexed_batch(stream, episodes, cap=CAP):
    table, counts = type_index(
        stream.types, stream.times, stream.n_types, cap)
    n = len(episodes[0].symbols)
    sym = jnp.asarray([e.symbols for e in episodes], jnp.int32)
    lo = jnp.asarray([e.t_low for e in episodes], jnp.float32).reshape(-1, n - 1)
    hi = jnp.asarray([e.t_high for e in episodes], jnp.float32).reshape(-1, n - 1)
    return table, counts, sym, lo, hi


def _dispatch(engine, times, lo, hi, pe, pc, *, parallel_schedule=False,
              chunk=8):
    cfg = tracking.EngineConfig(block_next=32, block_prev=32, chunk=chunk,
                                interpret=True)
    out = count_batch_dispatch(
        engine, jnp.asarray(times), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(pe, jnp.float32), jnp.asarray(pc, jnp.int32), cfg,
        parallel_schedule=parallel_schedule)
    return [np.asarray(x) for x in out]


# ---------------------------------------------------------------------------
# Engine x scheduler differential: fused == track+greedy == FSM oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("parallel_schedule", [False, True])
@pytest.mark.parametrize("seed", range(8))
def test_count_parity_across_engines_and_schedulers(seed, parallel_schedule):
    stream, episodes = _random_case(seed)
    table, counts, sym, lo, hi = _indexed_batch(stream, episodes)
    results = {}
    for engine in ENGINES:
        c, n, o = count_batch_indexed(
            table, counts, sym, lo, hi, engine=engine,
            parallel_schedule=parallel_schedule)
        assert not np.asarray(o).any()
        results[engine] = (np.asarray(c), np.asarray(n))
    for engine in ENGINES[1:]:
        np.testing.assert_array_equal(results[engine][0], results["dense"][0])
        np.testing.assert_array_equal(results[engine][1], results["dense"][1])
    for e, got in zip(episodes, results["dense_pallas_fused"][0]):
        assert int(got) == count_fsm_numpy(stream.types, stream.times, e)


# ---------------------------------------------------------------------------
# Carry-in/carry-out parity: the streaming stitch invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_stateful_carry_parity_kernel_vs_host_greedy(seed):
    """Non-trivial carries in, identical (count, end) carries out."""
    rng = np.random.default_rng(seed)
    b, n = 5, 3
    times = _batch_times(rng, b, n, CAP, empty_rows={(2, 1)})
    lo = rng.uniform(0, 1, (b, n - 1)).astype(np.float32)
    hi = (lo + rng.uniform(0.5, 4, (b, n - 1))).astype(np.float32)
    pe = np.where(rng.random(b) < 0.4, -np.inf,
                  rng.uniform(0, 80, b)).astype(np.float32)
    pc = rng.integers(0, 7, b).astype(np.int32)
    want = _dispatch("dense", times, lo, hi, pe, pc)
    got = _dispatch("dense_pallas_fused", times, lo, hi, pe, pc)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_streaming_stitch_split_equals_whole():
    """Split the stream at a gap wider than any window: counting the prefix
    fresh, then the suffix seeded with the prefix's carry, must equal one
    whole-stream count — on the fused path AND the track path."""
    rng = np.random.default_rng(11)
    n_types, half = 3, 40
    t_a = np.cumsum(rng.uniform(0.1, 1.0, half)).astype(np.float32)
    t_b = (t_a[-1] + 50.0
           + np.cumsum(rng.uniform(0.1, 1.0, half))).astype(np.float32)
    ty = rng.integers(0, n_types, 2 * half).astype(np.int32)
    whole = EventStream(ty, np.concatenate([t_a, t_b]), n_types)
    prefix = EventStream(ty[:half], t_a, n_types)
    suffix = EventStream(ty[half:], t_b, n_types)
    episodes = [serial(rng.integers(0, n_types, 3).tolist(), 0.0, 2.0)
                for _ in range(4)]
    b = len(episodes)
    fresh = (np.full(b, -np.inf, np.float32), np.zeros(b, np.int32))
    for engine in ("dense", "dense_pallas_fused"):
        def run(stream, pe, pc):
            table, counts, sym, lo, hi = _indexed_batch(stream, episodes)
            c, e, ns, o = count_batch_indexed_stateful(
                table, counts, sym, lo, hi, jnp.asarray(pe), jnp.asarray(pc),
                engine=engine)
            assert not np.asarray(o).any()
            return np.asarray(c), np.asarray(e)
        c_whole, e_whole = run(whole, *fresh)
        c_pre, e_pre = run(prefix, *fresh)
        c_stitch, e_stitch = run(suffix, e_pre, c_pre)
        np.testing.assert_array_equal(c_stitch, c_whole)
        np.testing.assert_array_equal(e_stitch, e_whole)


# ---------------------------------------------------------------------------
# Edge cases: padding, ties, ragged caps/chunks, single-symbol episodes
# ---------------------------------------------------------------------------


def test_all_padding_rows_pass_carry_through():
    rng = np.random.default_rng(0)
    b, n = 4, 3
    empty = {(i, s) for s in range(n) for i in (1, 3)}
    times = _batch_times(rng, b, n, CAP, empty_rows=empty)
    lo = np.zeros((b, n - 1), np.float32)
    hi = np.full((b, n - 1), 2.0, np.float32)
    pe = np.array([-np.inf, 5.0, 1.0, -np.inf], np.float32)
    pc = np.array([0, 3, 1, 2], np.int32)
    cnt, end, nsup, ovf = _dispatch("dense_pallas_fused",
                                    times, lo, hi, pe, pc)
    assert not ovf.any()
    np.testing.assert_array_equal(cnt[[1, 3]], pc[[1, 3]])
    np.testing.assert_array_equal(end[[1, 3]], pe[[1, 3]])
    np.testing.assert_array_equal(nsup[[1, 3]], [0, 0])
    want = _dispatch("dense", times, lo, hi, pe, pc)
    for w, g in zip(want, (cnt, end, nsup, ovf)):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("seed", range(4))
def test_duplicate_timestamp_ties(seed):
    """Integer-grid streams are full of equal end times; the kernel's strict
    ``start > prev_end`` rule must tie-break exactly like the host greedy."""
    rng = np.random.default_rng(seed)
    b, n = 4, 3
    times = np.full((b, n, CAP), np.inf, np.float32)
    for i in range(b):
        for s in range(n):
            n_real = int(rng.integers(10, CAP))
            times[i, s, :n_real] = np.sort(
                rng.integers(0, 12, n_real)).astype(np.float32)
    lo = np.zeros((b, n - 1), np.float32)
    hi = rng.uniform(1, 4, (b, n - 1)).astype(np.float32)
    pe = np.full(b, -np.inf, np.float32)
    pc = np.zeros(b, np.int32)
    want = _dispatch("dense", times, lo, hi, pe, pc)
    got = _dispatch("dense_pallas_fused", times, lo, hi, pe, pc)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("cap", [97, 130, 257])
def test_odd_and_prime_caps_pad_path(cap):
    rng = np.random.default_rng(cap)
    b, n = 3, 3
    times = _batch_times(rng, b, n, cap, empty_rows={(1, 0)})
    lo = rng.uniform(0, 1, (b, n - 1)).astype(np.float32)
    hi = (lo + rng.uniform(0.5, 4, (b, n - 1))).astype(np.float32)
    pe = np.full(b, -np.inf, np.float32)
    pc = np.zeros(b, np.int32)
    want = _dispatch("dense", times, lo, hi, pe, pc)
    got = _dispatch("dense_pallas_fused", times, lo, hi, pe, pc)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("batch,chunk", [(9, 8), (7, 3), (5, 16)])
def test_ragged_batch_over_chunk_grid(batch, chunk):
    """Batch sizes that don't divide the rows-per-grid-step chunk exercise
    the kernel's padded tail chunk."""
    rng = np.random.default_rng(batch * 31 + chunk)
    n = 3
    times = _batch_times(rng, batch, n, CAP)
    lo = rng.uniform(0, 1, (batch, n - 1)).astype(np.float32)
    hi = (lo + rng.uniform(0.5, 4, (batch, n - 1))).astype(np.float32)
    pe = np.full(batch, -np.inf, np.float32)
    pc = np.zeros(batch, np.int32)
    want = _dispatch("dense", times, lo, hi, pe, pc, chunk=chunk)
    got = _dispatch("dense_pallas_fused", times, lo, hi, pe, pc, chunk=chunk)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_single_symbol_episodes():
    """N=1: every first-symbol event is a point occurrence; the greedy fold
    reduces to counting strictly increasing finite times past the carry."""
    rng = np.random.default_rng(5)
    b = 4
    times = _batch_times(rng, b, 1, CAP, empty_rows={(2, 0)})
    times[3, 0, :6] = [1.0, 1.0, 2.0, 2.0, 2.0, 3.0]   # dupes: ties at N=1
    times[3, 0, 6:] = np.inf
    lo = np.zeros((b, 0), np.float32)
    hi = np.zeros((b, 0), np.float32)
    pe = np.array([-np.inf, 50.0, -np.inf, 1.5], np.float32)
    pc = np.array([0, 2, 0, 1], np.int32)
    want = _dispatch("dense", times, lo, hi, pe, pc)
    got = _dispatch("dense_pallas_fused", times, lo, hi, pe, pc)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    np.testing.assert_array_equal(got[0][3], 1 + 2)   # 2.0 and 3.0 past 1.5


# ---------------------------------------------------------------------------
# Compaction registry: every method dispatches, unknown names raise
# ---------------------------------------------------------------------------


def _compact_inputs():
    cap, cap_occ, max_window = 16, 8, 4
    t_sym = jnp.asarray(np.sort(np.random.default_rng(0).uniform(
        0, 10, cap)).astype(np.float32))
    wlo = jnp.asarray([0, 2, 5, 9, 0, 0, 0, 0], jnp.int32)
    counts = jnp.asarray([2, 1, 3, 0, 0, 0, 0, 0], jnp.int32)
    carried = jnp.asarray(
        [0.5, 1.0, 2.0, jnp.inf, jnp.inf, jnp.inf, jnp.inf, jnp.inf],
        jnp.float32)
    return t_sym, wlo, counts, carried, cap_occ, max_window


@pytest.mark.parametrize("method", sorted(compaction.METHODS))
def test_compact_accepts_every_registered_method(method):
    t_sym, wlo, counts, carried, cap_occ, max_window = _compact_inputs()
    new_t, new_c, n_out, overflow = compaction.compact(
        t_sym, wlo, counts, carried, cap_occ=cap_occ,
        max_window=max_window, method=method)
    assert new_t.shape == (cap_occ,)
    assert int(n_out) == int(jnp.sum(counts))
    assert not bool(overflow)


def test_compact_unknown_method_raises_value_error():
    t_sym, wlo, counts, carried, cap_occ, max_window = _compact_inputs()
    with pytest.raises(ValueError, match="count_scan_write"):
        compaction.compact(t_sym, wlo, counts, carried, cap_occ=cap_occ,
                           max_window=max_window, method="nope")
    with pytest.raises(ValueError, match="registered methods"):
        compaction.compact(t_sym, wlo, counts, carried, cap_occ=cap_occ,
                           max_window=max_window, method="")


# ---------------------------------------------------------------------------
# Bench gate: fused must be min-time in every cell (run.py --compare)
# ---------------------------------------------------------------------------


def test_fused_cell_failures_gate():
    from benchmarks.run import fused_cell_failures

    def entry(engine, us, batch=8, sched="scan"):
        return {"engine": engine, "scheduler": sched, "episode_len": 3,
                "n_events": 1024, "batch": batch, "us_per_call": us}

    # fused wins outright -> no failures
    assert fused_cell_failures(
        [entry("dense", 100.0), entry("dense_pallas_fused", 80.0)]) == []
    # fused within tolerance of the winner -> still passes
    assert fused_cell_failures(
        [entry("dense", 100.0), entry("dense_pallas_fused", 104.0)],
        tolerance=0.05) == []
    # fused loses a cell -> failure line names the actual winner
    fails = fused_cell_failures(
        [entry("dense", 100.0), entry("dense_pallas_fused", 150.0)],
        tolerance=0.05)
    assert len(fails) == 1 and "dense" in fails[0] and "150.0us" in fails[0]
    # fused missing from a cell -> failure, not a silent pass
    fails = fused_cell_failures([entry("dense", 100.0, batch=32)])
    assert len(fails) == 1 and "not covered" in fails[0]
    # cells are independent: one loss does not mask another cell's win
    fails = fused_cell_failures([
        entry("dense", 100.0), entry("dense_pallas_fused", 90.0),
        entry("dense", 50.0, sched="parallel"),
        entry("dense_pallas_fused", 200.0, sched="parallel")])
    assert len(fails) == 1 and "sched=parallel" in fails[0]
