"""Differential suite: the sharded miner against its single-device twin.

`mine_sharded == mine_arrays` (frequent sets, counts, candidate totals,
flag behavior) across engines x shard counts {1, 2, 8} on adversarial
streams — duplicate timestamps, prime shard lengths, episodes straddling
>= 3 shards — plus fixed regressions for the boundary-tie ownership rule
and the halo-adequacy `== span` edge. Everything multi-device runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(jax locks the device count at first init); the case generators live in
tests/strategies.py and the executable body in
tests/sharded_mining_child.py.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
CHILD = str(REPO / "tests" / "sharded_mining_child.py")
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def _run_child(*args, devices=8, timeout=900):
    env = dict(ENV)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run([sys.executable, CHILD, *args], env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=str(REPO))
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
    return r


@pytest.mark.slow
def test_halo_and_ownership_regressions_8dev():
    """Boundary-timestamp-tie ownership, the halo == span duplicate edge
    (flagged, never silent), per-episode flags in the batched path, and a
    >= 3-shard straddle — the fixed adversarial cases."""
    _run_child("halo", timeout=300)


@pytest.mark.slow
def test_differential_smoke_8dev():
    """A small always-on slice of the differential sweep (the full sweep
    is the slow-marked tests below)."""
    _run_child("differential", "--engine", "dense", "--examples", "8",
               timeout=300)


@pytest.mark.slow
def test_differential_dense_8dev():
    _run_child("differential", "--engine", "dense", "--examples", "100")


@pytest.mark.slow
def test_differential_fused_8dev():
    _run_child("differential", "--engine", "dense_pallas_fused",
               "--examples", "60")


@pytest.mark.slow
def test_differential_count_scan_write_8dev():
    # the faithful compaction pipeline compiles slowly under shard_map;
    # 15 examples here, the bulk of the >= 200-example budget rides the
    # dense/fused sweeps above
    _run_child("differential", "--engine", "count_scan_write",
               "--examples", "15")


@pytest.mark.slow
def test_differential_straddling_8dev():
    """Episodes straddling >= 3 shards: multi-hop halo exactness."""
    _run_child("straddle", "--examples", "40")


# ---------------------------------------------------------------------------
# Single-device pieces of the sharded machinery (no subprocess needed)
# ---------------------------------------------------------------------------


def test_shard_stream_pads_and_reshapes():
    from repro.core import shard_stream
    ty, tm = shard_stream(np.arange(5, dtype=np.int32),
                          np.arange(5, dtype=np.float32), 3)
    assert ty.shape == tm.shape == (3, 2)
    assert ty[2, 1] == -1 and np.isinf(tm[2, 1])
    # a stream shorter than the shard count still yields one event per shard
    ty, tm = shard_stream(np.zeros(2, np.int32), np.zeros(2, np.float32), 8)
    assert ty.shape == (8, 1) and (ty[2:] == -1).all()


def test_type_index_drops_negative_padding_types():
    """-1 padded types must not wrap into the last type's row (jax scatter
    indices wrap): before the fix they inflated its count and raced +inf
    writes against its real times."""
    import jax.numpy as jnp
    from repro.core.events import type_index
    types = jnp.asarray([2, -1, 2, -1, -1], jnp.int32)
    times = jnp.asarray([1.0, jnp.inf, 2.0, jnp.inf, jnp.inf], jnp.float32)
    table, counts = type_index(types, times, 3, 5)
    np.testing.assert_array_equal(np.asarray(counts), [0, 0, 2])
    np.testing.assert_array_equal(np.asarray(table[2][:2]), [1.0, 2.0])


def test_single_shard_sharded_mining_matches_unsharded():
    """n_shards=1 on the default mesh: the whole sharded pipeline (index
    build, ownership, merge) degenerates to the single-device answer."""
    from repro.core import MinerConfig, mine_arrays
    from repro.launch.mesh import make_mesh
    rng = np.random.default_rng(3)
    from repro.core.events import EventStream
    n = 120
    stream = EventStream(rng.integers(0, 4, n).astype(np.int32),
                         np.cumsum(rng.exponential(0.4, n)).astype(np.float32),
                         4)
    kw = dict(t_low=0.0, t_high=2.0, threshold=6, max_level=3)
    base = mine_arrays(stream, MinerConfig(**kw))
    mesh = make_mesh((1,), ("data",))
    got = mine_arrays(stream, MinerConfig(**kw, mesh=mesh, halo=64))
    assert base.keys() == got.keys()
    for lvl in base:
        np.testing.assert_array_equal(base[lvl].symbols, got[lvl].symbols)
        np.testing.assert_array_equal(base[lvl].counts, got[lvl].counts)
        assert base[lvl].n_candidates == got[lvl].n_candidates


def test_mine_sharded_requires_mesh():
    from repro.core import MinerConfig, mine_sharded
    from repro.core.events import EventStream
    s = EventStream(np.zeros(4, np.int32), np.arange(4, dtype=np.float32), 2)
    with pytest.raises(ValueError, match="mesh"):
        mine_sharded(s, MinerConfig(t_low=0.0, t_high=1.0, threshold=1))


def test_count_sharded_rejects_mismatched_mesh():
    from repro.core import serial
    from repro.core.distributed import count_sharded
    from repro.launch.mesh import make_mesh
    import jax.numpy as jnp
    mesh = make_mesh((1,), ("data",))
    ty = jnp.zeros((2, 4), jnp.int32)
    tm = jnp.zeros((2, 4), jnp.float32)
    with pytest.raises(ValueError, match="mesh axis"):
        count_sharded(ty, tm, serial([0, 1], 0.0, 1.0), mesh, n_types=2)
