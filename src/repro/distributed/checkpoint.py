"""Mesh-shape-agnostic checkpointing with async save.

Leaves are written as one ``.npz`` per host (this process writes its
addressable shards; on multi-host each process writes its own file) plus a
msgpack manifest (step, tree structure, leaf shapes/dtypes). Restore
re-shards every leaf onto the *current* mesh — which may differ from the
save-time mesh — so a 512-chip job restarts on 256 healthy chips (elastic
re-mesh, see fault_tolerance.py).

Save is asynchronous: device->host transfer happens synchronously (cheap),
serialization + fsync run on a worker thread so the train loop is not
blocked (the distributed-optimization trick of overlapping checkpoint I/O
with compute).
"""
from __future__ import annotations

import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------- save ----------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk on a worker thread."""
        self.wait()
        flat, _ = _flatten_with_paths(tree)
        host_leaves = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

        def _write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            tmp.mkdir(parents=True, exist_ok=True)
            # npz cannot roundtrip ml_dtypes (bf16/fp8): store bit-views
            arrays = {self._safe(k): (v.view(np.uint16)
                                      if v.dtype.name == "bfloat16" else v)
                      for k, v in host_leaves}
            np.savez(tmp / "shards_p0.npz", **arrays)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": [
                    {"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in host_leaves
                ],
            }
            (tmp / "manifest.msgpack").write_bytes(
                msgpack.packb(manifest, use_bin_type=True))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)          # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    @staticmethod
    def _safe(key: str) -> str:
        return key.replace("/", "_")

    # ------------------------------ restore --------------------------------

    def list_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, example_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint into the structure of ``example_tree``,
        placing each leaf with ``shardings`` (tree of NamedShardings) if
        given — this is where elastic re-mesh happens."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "shards_p0.npz")
        flat, treedef = _flatten_with_paths(example_tree)
        sh_flat = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat))
        # shardings tree may be structured like example_tree
        out = []
        for (key, example), sh in zip(flat, sh_flat):
            arr = data[self._safe(key)]
            want = np.dtype(jax.numpy.asarray(example).dtype
                            if not hasattr(example, "dtype") else example.dtype)
            if want.name == "bfloat16" and arr.dtype == np.uint16:
                arr = arr.view(want)
            else:
                arr = arr.astype(want, copy=False)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out)
