"""Logical-axis sharding rules (DP / TP / EP / SP + pod axis).

Every weight/cache leaf declares logical axis names (the *_specs() twins in
models/); this module resolves them against a concrete mesh with
divisibility checks — e.g. recurrentgemma's 10 attention heads do not divide
model=16, so its q_proj falls back to replication while its ffn (7680 % 16
== 0) stays tensor-parallel. That makes every (arch x shape x mesh) cell
well-defined without per-arch hand tuning, which is what you need when a
1000-node job has to restart on a differently-shaped healthy subset.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None]

# weight/cache logical axes -> mesh axes (tuples = try in order, first fit)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),       # combined DP over pods
    "vocab": "model",
    "embed": None,                  # weight d_model dim replicated
    "ff": "model",                  # Megatron column/row TP
    "q_proj": "model",
    "kv_proj": "model",
    "experts": "model",             # EP
    "heads": "model",
    "kv_heads": "model",
    "cache_seq": "model",           # SP over the KV cache (flash-decoding split)
    "seq": None,
    "layers": None,                 # scan axis
    "ff_inner": None,               # expert-hidden dim (model axis is on E)
}

# activation name -> logical axes per dim
ACTIVATION_AXES: Dict[str, Tuple[Logical, ...]] = {
    "hidden": ("batch", "seq", "embed"),
    "logits": ("batch", None, "vocab"),
    "decode_hidden": ("batch", "seq", "embed"),
    "tokens": ("batch", "seq"),
    "tokens_1d": ("batch",),
    "patches": ("batch", "seq", None),
    "attn_heads": ("batch", None, "heads", None),
    "moe_buffer": ("experts", "batch", None),
    "moe_hidden": ("experts", "batch", "ff_inner"),
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    rules: Dict[str, Any] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    # ------------------------- spec resolution -----------------------------

    def _mesh_axes_for(self, logical: Logical, dim: int,
                       used: set) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        rule = self.rules.get(logical)
        if rule is None:
            return None
        candidates = rule if isinstance(rule, tuple) else (rule,)
        picked = []
        size = 1
        for ax in candidates:
            if ax not in self.mesh.axis_names or ax in used:
                continue
            if dim % (size * self.mesh.shape[ax]) == 0:
                picked.append(ax)
                size *= self.mesh.shape[ax]
        return tuple(picked) or None

    def spec_for(self, logical_axes: Sequence[Logical],
                 shape: Sequence[int]) -> P:
        if len(logical_axes) != len(shape):
            # trailing unnamed dims replicate
            logical_axes = (tuple(logical_axes)
                            + (None,) * (len(shape) - len(logical_axes)))
        used: set = set()
        parts = []
        for logical, dim in zip(logical_axes, shape):
            axes = self._mesh_axes_for(logical, int(dim), used)
            if axes is None:
                parts.append(None)
            else:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding_for(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))

    def zero_spec(self, spec: P, shape) -> P:
        """ZeRO-style augmentation: additionally shard the first divisible
        unsharded dim over the data axis (master params / optimizer state;
        GSPMD inserts the per-use all-gathers)."""
        parts = list(spec) + [None] * (len(shape) - len(spec))
        used = {a for p in parts if p
                for a in (p if isinstance(p, tuple) else (p,))}
        if "data" in used or "data" not in self.mesh.axis_names:
            return P(*parts)
        n = self.mesh.shape["data"]
        for i, (p, dim) in enumerate(zip(parts, shape)):
            if p is None and int(dim) % n == 0 and int(dim) >= n:
                parts[i] = "data"
                break
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def tree_shardings_zero(self, spec_tree, shape_tree):
        base = self.tree_shardings(spec_tree, shape_tree)
        shapes = jax.tree.leaves(shape_tree)
        flat, treedef = jax.tree.flatten(base)
        out = [NamedSharding(self.mesh, self.zero_spec(ns.spec, sh.shape))
               for ns, sh in zip(flat, shapes)]
        return jax.tree.unflatten(treedef, out)

    # --------------------------- tree helpers ------------------------------

    def tree_shardings(self, spec_tree, shape_tree):
        """Zip a logical-spec tree against abstract shapes -> NamedShardings."""
        def is_spec(v):
            return isinstance(v, tuple)
        flat_specs, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
        flat_shapes = jax.tree.leaves(shape_tree)
        if len(flat_specs) != len(flat_shapes):
            raise ValueError(
                f"spec tree ({len(flat_specs)}) != shape tree ({len(flat_shapes)})")
        out = [
            self.sharding_for(sp, sh.shape)
            for sp, sh in zip(flat_specs, flat_shapes)
        ]
        return jax.tree.unflatten(treedef, out)

    # ------------------------ activation constraints -----------------------

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        axes = ACTIVATION_AXES.get(name)
        if axes is None:
            return x
        spec = self.spec_for(axes, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def batch_shardings(self, batch_specs: Dict[str, jax.ShapeDtypeStruct]):
        """Input shardings for a train/serve batch dict."""
        out = {}
        for k, sds in batch_specs.items():
            if k in ("tokens", "targets", "loss_mask"):
                name = "tokens" if len(sds.shape) == 2 else "tokens_1d"
            elif k == "patches":
                name = "patches"
            elif k == "pos":
                name = "tokens_1d"
            else:
                name = "tokens"
            out[k] = self.sharding_for(ACTIVATION_AXES[name], sds.shape)
        return out


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
