"""Fault tolerance & elastic scaling for the multi-pod runtime.

Components:

* ``RunState`` + ``resilient_train_loop`` — checkpoint/restart training:
  periodic async checkpoints, crash recovery from the latest step, step
  timing telemetry feeding the paper's episode miner.

* ``StragglerMonitor`` — per-host step-duration telemetry -> SLOW(h) event
  stream -> non-overlapped count of the chained-slowness episode
  (core/telemetry.py). Hosts whose score crosses the threshold are
  reported for mitigation (demotion/eviction at the scheduler level). This
  is the paper's technique running on the framework's own control plane.

* ``elastic_remesh`` — rebuild a (possibly smaller) mesh from currently
  healthy devices and restore the latest checkpoint onto it. Checkpoints
  are saved unsharded per leaf (distributed/checkpoint.py), so any mesh
  whose axes divide the layer dimensions can resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..core import telemetry as tele
from .checkpoint import Checkpointer
from .sharding import MeshRules


@dataclasses.dataclass
class StragglerMonitor:
    window: float = 30.0        # seconds within which repeats chain
    repeat: int = 3             # SLOW events chained to flag
    slow_factor: float = 1.5
    min_count: int = 2
    log: tele.TelemetryLog = dataclasses.field(default_factory=tele.TelemetryLog)
    _step_times: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    _wall: List[float] = dataclasses.field(default_factory=list)
    _sessions: Optional[tele.StragglerSessions] = None

    def record_step(self, host_durations: Dict[str, float], wall: float) -> None:
        if self._sessions is None:
            self._sessions = tele.StragglerSessions(
                window=self.window, repeat=self.repeat,
                hosts_hint=max(len(host_durations), 1))
        self._wall.append(wall)
        durs = list(host_durations.values())
        med = float(np.median(durs)) if durs else 0.0
        for h, d in host_durations.items():
            self._step_times.setdefault(h, []).append(d)
            if med > 0 and d > self.slow_factor * med:
                self.log.emit(f"SLOW:{h}", wall)
                # live path: the SLOW event streams into the host's serving
                # session as it happens (buffered; scores() flushes the pool)
                self._sessions.observe(h, [wall])

    def scores(self) -> Dict[str, int]:
        """Per-host chained-SLOW scores from the serving pool — every
        host's session absorbed and mined in ONE batched flush (identical
        counts to the cold per-host ``tele.straggler_scores`` loop; the
        batch path stays available on the accumulated ``self.log``)."""
        if self._sessions is None:
            return {}
        return self._sessions.scores()

    def flagged(self) -> List[str]:
        return [h for h, c in self.scores().items() if c >= self.min_count]


def elastic_remesh(target_shape, axis_names, *, rules_cls=MeshRules):
    """Build a mesh over the currently-available devices. If fewer devices
    than requested survive, shrink the leading (data) axis."""
    devs = jax.devices()
    shape = list(target_shape)
    while int(np.prod(shape)) > len(devs) and shape[0] > 1:
        shape[0] //= 2
    n = int(np.prod(shape))
    mesh = jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(shape), axis_names)
    return mesh, rules_cls(mesh)


def resilient_train_loop(
    *,
    step_fn: Callable,                      # (state..., batch) -> state..., metrics
    init_state: Any,
    batch_iter,
    checkpointer: Checkpointer,
    n_steps: int,
    ckpt_every: int = 50,
    monitor: Optional[StragglerMonitor] = None,
    host_name: str = "host0",
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    resume: bool = True,
    fail_injector: Optional[Callable[[int], None]] = None,
):
    """Run ``step_fn`` with periodic async checkpoints and crash recovery.

    Returns (final_state, start_step_after_any_resume, metrics_history).
    ``fail_injector(step)`` may raise to simulate failures (tests); the
    loop checkpoints, the caller restarts, and ``resume=True`` continues
    from the latest published step.
    """
    start = 0
    state = init_state
    if resume and checkpointer.latest_step() is not None:
        start = checkpointer.latest_step()
        state = checkpointer.restore(init_state)
    history = []
    try:
        for step in range(start, n_steps):
            t0 = time.time()
            if fail_injector is not None:
                fail_injector(step)
            batch = next(batch_iter)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            if monitor is not None:
                monitor.record_step({host_name: dt}, time.time())
            history.append({k: float(v) for k, v in metrics.items()})
            if on_metrics:
                on_metrics(step, history[-1])
            if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                checkpointer.save(step + 1, state)
    finally:
        # flush any in-flight async save even on crash, so the restart
        # resumes from the newest published step
        checkpointer.wait()
    return state, start, history
