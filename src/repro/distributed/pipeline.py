"""GPipe-style pipeline parallelism over the pod axis (shard_map + ppermute).

The default multi-pod configuration treats ``pod`` as pure DP (one gradient
all-reduce per step). When cross-pod bandwidth is the binding constraint,
pipelining over pods trades the full-gradient all-reduce for per-microbatch
boundary-activation permutes. This module provides the forward schedule as
a composable primitive:

  * layers are split into ``n_stages`` contiguous groups (stage s owns its
    slice of the stacked layer params — sharded over the pipeline axis);
  * the classic looped-pipeline schedule runs ``n_micro + n_stages - 1``
    ticks; on each tick every stage processes one resident microbatch and
    ships its output to the next stage with ``lax.ppermute`` (compute and
    the boundary permute overlap across stages by construction);
  * stage-0 injects microbatches, the last stage emits them.

Supports inference/forward pipelines directly; for training it composes
with jax.grad through the shard_map (ppermute transposes to the reverse
permute), demonstrating the collective pattern the dry-run measures.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import pcast_varying, shard_map


def pipeline_forward(
    stage_fn: Callable,       # (stage_params, x) -> x, applied per stage
    params_stacked,           # pytree, leaves [n_stages, ...]
    x_micro,                  # [n_micro, micro_batch, ...] microbatched input
    mesh: Mesh,
    axis: str = "pod",
):
    """Run the looped pipeline over mesh axis ``axis``.

    Returns outputs [n_micro, micro_batch, ...] (produced by the last
    stage, gathered to all stages for downstream loss computation).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    if n_micro < n_stages:
        raise ValueError("need n_micro >= n_stages to fill the pipeline")

    def stage_local(params_blk, x_blk):
        # params_blk: leaves [1, ...] (this stage's slice); x_blk: [n_micro, ...]
        params = jax.tree.map(lambda a: a[0], params_blk)
        sid = lax.axis_index(axis)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        buf = jnp.zeros_like(x_blk[0])          # resident activation
        outs = jnp.zeros_like(x_blk)
        # the loop makes these pod-varying; mark the initial values so the
        # scan carry types match (shard_map varying-manual-axes rule)
        buf = pcast_varying(buf, (axis,))
        outs = pcast_varying(outs, (axis,))

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when available)
            inject = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where((sid == 0) & (t < n_micro),
                             x_blk[inject], buf)
            y = stage_fn(params, x_in)
            # last stage banks its finished microbatch m = t - (n_stages-1)
            m = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(
                bank,
                lax.dynamic_update_index_in_dim(outs, y, m, 0),
                outs)
            # ship boundary activations to the next stage
            buf = lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = lax.scan(tick, (buf, outs),
                                  jnp.arange(n_micro + n_stages - 1))
        # broadcast the last stage's outputs to every stage
        outs = lax.psum(jnp.where(sid == n_stages - 1, outs, 0.0), axis)
        return outs

    fn = shard_map(
        stage_local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(params_stacked, x_micro)
