from .sharding import MeshRules, DEFAULT_RULES, ACTIVATION_AXES, replicated
from .checkpoint import Checkpointer
from .fault_tolerance import StragglerMonitor, elastic_remesh, resilient_train_loop
from .pipeline import pipeline_forward

__all__ = ["MeshRules", "DEFAULT_RULES", "ACTIVATION_AXES", "replicated",
           "Checkpointer", "StragglerMonitor", "elastic_remesh",
           "resilient_train_loop", "pipeline_forward"]
