from .pipeline import DataConfig, SyntheticCorpus, token_event_stream
from .spikes import (NetworkConfig, PAPER_DATASETS, embedded_episodes,
                     paper_dataset, simulate)

__all__ = ["DataConfig", "SyntheticCorpus", "token_event_stream",
           "NetworkConfig", "PAPER_DATASETS", "embedded_episodes",
           "paper_dataset", "simulate"]
