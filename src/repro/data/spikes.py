"""Multi-neuron spike-train simulator (paper §V-A).

Inhomogeneous-Poisson network model of [Patnaik et al. 2008]: each of
``n_neurons`` artificial neurons fires at a base rate (paper: 64 neurons,
20 spikes/s of noise); directed connections raise the firing probability of
downstream neurons inside a delay window, so embedded cascades appear as
frequent serial episodes with inter-event constraints. Four 9-node episodes
are embedded by strengthening chains of connections, mirroring the paper's
datasets (Table II: 20 s .. 4000 s of simulated time).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from ..core.episodes import Episode
from ..core.events import EventStream


@dataclasses.dataclass
class NetworkConfig:
    n_neurons: int = 64
    base_rate: float = 20.0        # spontaneous spikes/s/neuron (noise)
    conn_strength: float = 0.9     # firing prob boost along a cascade edge
    delay_low: float = 0.001       # seconds (paper windows are ms-scale)
    delay_high: float = 0.005
    dt: float = 0.001              # simulation tick
    trigger_hz: float = 6.0        # cascade injection rate per episode
    n_embedded: int = 4
    episode_len: int = 9
    seed: int = 0


def embedded_episodes(cfg: NetworkConfig) -> List[Episode]:
    """The cascades wired into the network, as Episode objects (constraints
    in the same units as simulated time)."""
    rng = np.random.default_rng(cfg.seed)
    eps = []
    perm = rng.permutation(cfg.n_neurons)
    for i in range(cfg.n_embedded):
        syms = perm[i * cfg.episode_len:(i + 1) * cfg.episode_len]
        eps.append(Episode(
            tuple(int(s) for s in syms),
            (0.0,) * (cfg.episode_len - 1),
            (cfg.delay_high * 2,) * (cfg.episode_len - 1),
        ))
    return eps


def simulate(cfg: NetworkConfig, duration_s: float) -> EventStream:
    """Generate a spike train of ``duration_s`` seconds."""
    rng = np.random.default_rng(cfg.seed + 1)
    episodes = embedded_episodes(cfg)

    # base Poisson noise
    n_expect = cfg.base_rate * cfg.n_neurons * duration_s
    n_noise = rng.poisson(n_expect)
    t_noise = rng.uniform(0.0, duration_s, n_noise)
    e_noise = rng.integers(0, cfg.n_neurons, n_noise)

    # cascade injections: each episode triggers at ~trigger_hz; each trigger
    # walks the chain with per-edge success prob conn_strength and a random
    # delay in (delay_low, delay_high]
    t_extra, e_extra = [], []
    for ep in episodes:
        triggers = rng.uniform(0.0, duration_s,
                               max(1, rng.poisson(cfg.trigger_hz * duration_s)))
        for t0 in triggers:
            t = t0
            for sym in ep.symbols:
                t_extra.append(t)
                e_extra.append(sym)
                if rng.uniform() > cfg.conn_strength:
                    break
                t = t + rng.uniform(cfg.delay_low, cfg.delay_high)

    times = np.concatenate([t_noise, np.asarray(t_extra, np.float64)])
    types = np.concatenate([e_noise, np.asarray(e_extra, np.int64)])
    order = np.argsort(times, kind="stable")
    return EventStream(types[order].astype(np.int32),
                       times[order].astype(np.float32), cfg.n_neurons)


# Paper Table II dataset definitions (duration seconds). Events counts in
# the paper (~3.2k events/s) come from 64 neurons x ~50 sp/s including
# cascade traffic; our defaults reproduce the same scaling shape.
def noise_pair_estimate(cfg: NetworkConfig, duration_s: float) -> float:
    """Expected chance count of a 2-node episode under pure noise: events of
    the first type x P(second type within the window)."""
    w = 2 * cfg.delay_high
    return (cfg.base_rate * duration_s) * (cfg.base_rate * w)


PAPER_DATASETS: Tuple[Tuple[int, float], ...] = (
    (1, 4000.0), (2, 2000.0), (3, 1000.0), (4, 500.0),
    (5, 200.0), (6, 100.0), (7, 50.0), (8, 20.0),
)


def paper_dataset(idx: int, *, scale: float = 1.0,
                  cfg: NetworkConfig = None) -> EventStream:
    """Dataset ``idx`` (1..8) from Table II, optionally time-scaled down
    (CPU benchmarks use scale < 1 to bound runtime; the *relative* curves
    match the paper's figures)."""
    cfg = cfg or NetworkConfig()
    durations = dict(PAPER_DATASETS)
    return simulate(cfg, durations[idx] * scale)
