"""Token data pipeline: synthetic corpora, packing, shard-aware batching."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    kind: str = "markov"      # markov | uniform | repeat


class SyntheticCorpus:
    """Deterministic synthetic token streams with learnable structure.

    ``markov`` draws from a sparse random bigram chain (low entropy, so a
    ~100M model visibly reduces loss within a few hundred steps — used by
    examples/train_lm.py); ``repeat`` emits noisy repeated motifs (the LM
    analogue of the paper's embedded episodes).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        if cfg.kind == "markov":
            fanout = 2
            self.next_tokens = rng.integers(0, v, size=(v, fanout))
        elif cfg.kind == "repeat":
            self.motifs = [rng.integers(0, v, size=rng.integers(4, 12))
                           for _ in range(32)]
        self.rng = rng

    def _sequence(self, rng, n: int) -> np.ndarray:
        cfg = self.cfg
        if cfg.kind == "uniform":
            return rng.integers(0, cfg.vocab, n)
        if cfg.kind == "markov":
            out = np.empty(n, np.int64)
            t = rng.integers(0, cfg.vocab)
            for i in range(n):
                out[i] = t
                t = self.next_tokens[t, rng.integers(0, self.next_tokens.shape[1])]
            return out
        # repeat: motifs separated by noise
        out = []
        while len(out) < n:
            m = self.motifs[rng.integers(0, len(self.motifs))]
            out.extend(m.tolist())
            out.extend(rng.integers(0, cfg.vocab, rng.integers(1, 6)).tolist())
        return np.asarray(out[:n])

    def batches(self, *, frontend: Optional[str] = None,
                arch: Optional[ArchConfig] = None) -> Iterator[Dict[str, jax.Array]]:
        cfg = self.cfg
        step = 0
        while True:
            rng = np.random.default_rng((cfg.seed, step))
            b, s = cfg.global_batch, cfg.seq_len
            if frontend == "vision" and arch is not None:
                s_text = s - arch.n_patches
                toks = np.stack([self._sequence(rng, s_text + 1) for _ in range(b)])
                batch = {
                    "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                    "targets": jnp.asarray(toks[:, 1:], jnp.int32),
                    "patches": jnp.asarray(
                        rng.normal(size=(b, arch.n_patches, arch.d_patch)),
                        jnp.float32),
                }
            else:
                toks = np.stack([self._sequence(rng, s + 1) for _ in range(b)])
                batch = {
                    "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                    "targets": jnp.asarray(toks[:, 1:], jnp.int32),
                }
            yield batch
            step += 1


def token_event_stream(tokens: np.ndarray, n_types: int):
    """View a token sequence as the paper's event stream: event type =
    token id (mod n_types), time = position. Lets the miner run over LM
    data (e.g. MusicGen EnCodec codes)."""
    from ..core.events import EventStream
    tokens = np.asarray(tokens).reshape(-1)
    return EventStream((tokens % n_types).astype(np.int32),
                       np.arange(tokens.size, dtype=np.float32), n_types)
