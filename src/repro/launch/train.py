"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        [--reduced] [--steps 100] [--mesh-shape 1,1] [--resume] \
        [--ckpt-dir /tmp/ckpt] [--compress-grads]

Full configs need the full mesh (run under the dry-run device flags on a
real pod); `--reduced` trains the smoke-scale config of the same family on
whatever devices exist — the same code path either way: sharding rules,
AdamW, async checkpoints, crash-resume, straggler telemetry.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config, reduced as reduce_cfg
from ..data import DataConfig, SyntheticCorpus
from ..distributed.checkpoint import Checkpointer
from ..distributed.fault_tolerance import StragglerMonitor, resilient_train_loop
from ..distributed.sharding import MeshRules
from ..models import Model
from ..optim import AdamW, compression
from ..train import make_train_step
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh-shape", default=None, help="e.g. 1,1 or 2,4")
    ap.add_argument("--remat", default="none",
                    choices=("full", "dots", "dots_no_batch", "none"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
    else:
        shape = (1, jax.device_count())
    mesh = make_mesh(shape, ("data", "model")[-len(shape):]
                     if len(shape) <= 2 else ("pod", "data", "model"))
    rules = MeshRules(mesh)
    model = Model(cfg, constrain=rules.constrain, remat=args.remat, mesh=mesh)
    opt = AdamW(lr=args.lr, warmup_steps=max(5, args.steps // 10),
                total_steps=args.steps)

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"remat={args.remat}", flush=True)

    opt_state = opt.init(params)
    data = SyntheticCorpus(DataConfig(
        seq_len=args.seq_len, global_batch=args.batch, vocab=cfg.vocab))
    batches = data.batches(frontend=cfg.frontend, arch=cfg)

    if args.compress_grads:
        err0 = compression.init_error_state(params)
        raw = jax.jit(make_train_step(model, opt, compress=True),
                      donate_argnums=(0, 1, 3))

        def step_fn(state, batch):
            params, opt_state, err, key = state
            key, sub = jax.random.split(key)
            params, opt_state, err, m = raw(params, opt_state, batch, err, sub)
            return (params, opt_state, err, key), m
        state0 = (params, opt_state, err0, jax.random.PRNGKey(1))
    else:
        raw = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, m = raw(params, opt_state, batch)
            return (params, opt_state), m
        state0 = (params, opt_state)

    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    mon = StragglerMonitor()

    def on_metrics(step, m):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
                  f"gnorm {m['grad_norm']:.2f}", flush=True)

    t0 = time.time()
    state, start, hist = resilient_train_loop(
        step_fn=step_fn, init_state=state0, batch_iter=batches,
        checkpointer=ckpt, n_steps=args.steps, ckpt_every=args.ckpt_every,
        monitor=mon, on_metrics=on_metrics, resume=args.resume)
    dt = time.time() - t0
    print(f"done: steps {start}..{args.steps} in {dt:.0f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"stragglers={mon.flagged()}")


if __name__ == "__main__":
    main()
