"""Production meshes. Defined as functions so importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; older versions default to
    Auto semantics, so omitting it is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh use smaller shapes)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(len(axes)))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch (pod acts as extra data parallelism)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
