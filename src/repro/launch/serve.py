"""Batched serving launcher: continuous decode with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        [--batch 4] [--cache-len 256] [--requests 8] [--max-new 32]

Implements the decode_* dry-run cells at runnable scale: a fixed-size
decode batch over a KV cache, slot-per-request scheduling (a finished
request frees its slot for the next queued prompt — continuous batching).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced as reduce_cfg
from ..distributed.sharding import MeshRules
from ..models import Model
from ..train import make_serve_step
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    rules = MeshRules(mesh)
    model = Model(cfg, constrain=rules.constrain, remat="none", mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    b = args.batch
    cache = model.init_cache(b, args.cache_len)
    rng = np.random.default_rng(0)
    queue = list(rng.integers(0, cfg.vocab, size=(args.requests,)))
    slot_tokens = jnp.zeros((b,), jnp.int32)
    slot_pos = jnp.zeros((b,), jnp.int32)
    slot_remaining = np.zeros((b,), np.int64)
    slot_req = -np.ones((b,), np.int64)
    done = 0
    next_req = 0
    produced = {i: [] for i in range(args.requests)}

    t0 = time.time()
    n_steps = 0
    while done < args.requests:
        # fill free slots from the queue (continuous batching)
        for i in range(b):
            if slot_remaining[i] == 0 and next_req < len(queue):
                slot_tokens = slot_tokens.at[i].set(int(queue[next_req]))
                slot_pos = slot_pos.at[i].set(0)
                slot_remaining[i] = args.max_new
                slot_req[i] = next_req
                next_req += 1
        logits, cache = step(params, cache, slot_tokens, slot_pos)
        key = jax.random.fold_in(jax.random.PRNGKey(7), n_steps)
        nxt = jax.random.categorical(key, logits / args.temperature, axis=-1)
        slot_tokens = nxt.astype(jnp.int32)
        slot_pos = slot_pos + 1
        n_steps += 1
        host_next = np.asarray(nxt)
        for i in range(b):
            if slot_remaining[i] > 0:
                produced[int(slot_req[i])].append(int(host_next[i]))
                slot_remaining[i] -= 1
                if slot_remaining[i] == 0:
                    done += 1
    dt = time.time() - t0
    total = sum(len(v) for v in produced.values())
    print(f"served {args.requests} requests ({total} tokens) in {dt:.1f}s "
          f"({total/dt:.1f} tok/s, {n_steps} decode steps)")
    print("request 0 tokens:", produced[0][:12])


if __name__ == "__main__":
    main()
