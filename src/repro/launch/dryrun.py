import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init. Tests may shrink the pool via REPRO_DRYRUN_DEVICES.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
resolves, collectives legal, memory fits) and extracts the roofline terms:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...).lower(**input_specs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # fits?
        compiled.cost_analysis()     # FLOPs / bytes for the roofline

Results are written incrementally as JSON under --out (default
experiments/dryrun/<mesh>/<arch>__<shape>.json).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--remat full]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..analysis import hlo_costs
from ..analysis import roofline as rl
from ..configs import (SHAPES, applicable, get_config, input_specs,
                       list_archs, n_active_params, reduced)
from ..distributed.sharding import MeshRules, replicated
from ..models.model import Model
from ..optim.adamw import AdamW, AdamWState
from ..train import steps as steps_lib
from .mesh import make_production_mesh, make_mesh


def _memstats_dict(ma) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def lower_cell(cfg, shape, mesh, *, remat: str = "full",
               batch_override: int = 0, extra_rules=None, zero: bool = False):
    """Build + lower + compile one cell; returns (compiled, report_dict)."""
    rules = MeshRules(mesh)
    if extra_rules:
        rules.rules.update(extra_rules)
    model = Model(cfg, constrain=rules.constrain, remat=remat, mesh=mesh)
    specs = input_specs(cfg, shape, batch_override)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    params_shapes = jax.eval_shape(lambda: model.init(key))
    param_sh = rules.tree_shardings(model.param_specs(), params_shapes)
    batch_sh = rules.batch_shardings(specs)

    if shape.kind == "train":
        opt = AdamW()
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        if zero:
            # ZeRO: master params + moments additionally sharded over data
            param_sh = rules.tree_shardings_zero(model.param_specs(),
                                                 params_shapes)
            zsh = param_sh
        else:
            zsh = param_sh
        opt_sh = AdamWState(count=replicated(mesh), mu=zsh, nu=zsh)
        step = steps_lib.make_train_step(model, opt)
        jf = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        lowered = jf.lower(params_shapes, opt_shapes, specs)
    elif shape.kind == "prefill":
        step = steps_lib.make_prefill_step(model)
        jf = jax.jit(step, in_shardings=(param_sh, batch_sh),
                     out_shardings=None)
        lowered = jf.lower(params_shapes, specs)
    else:  # decode
        b = batch_override or shape.global_batch
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len))
        cache_sh = rules.tree_shardings(model.cache_specs(), cache_shapes)
        step = steps_lib.make_serve_step(model)
        jf = jax.jit(step,
                     in_shardings=(param_sh, cache_sh,
                                   batch_sh["tokens"], batch_sh["pos"]),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
        lowered = jf.lower(params_shapes, cache_shapes,
                           specs["tokens"], specs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):   # older jax: list of dicts
        raw_cost = raw_cost[0]
    raw_cost = dict(raw_cost)
    ma = compiled.memory_analysis()
    memstats = _memstats_dict(ma)
    # trip-count-corrected per-device costs from the optimized HLO
    # (cost_analysis counts while bodies once — see analysis/hlo_costs.py)
    parsed = hlo_costs.module_costs(compiled.as_text())
    cost = {"flops": parsed["flops"], "bytes accessed": parsed["hbm_bytes"]}
    coll = parsed["coll"]
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    roof = rl.analyze(
        arch=cfg.name, shape=shape.name, mesh_name=mesh_name, chips=chips,
        cost=cost,
        coll=coll, model_flops=rl.model_flops_for(cfg, shape, n_active_params(cfg)),
        memstats=memstats)
    op_mix = dict(sorted(parsed["op_mix"].items(),
                         key=lambda kv: -kv[1])[:24])
    report = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "chips": chips, "remat": remat,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": memstats,
        "bytes_per_device_resident": memstats["argument_size_in_bytes"]
        + memstats["temp_size_in_bytes"],
        "cost_analysis_raw": {k: float(v) for k, v in raw_cost.items()
                              if k in ("flops", "bytes accessed",
                                       "transcendentals", "optimal_seconds")},
        "hlo_costs": {"flops": parsed["flops"],
                      "hbm_bytes": parsed["hbm_bytes"]},
        "collective_bytes": coll,
        "op_mix": op_mix,
        "roofline": roof.asdict(),
        "status": "ok",
    }
    return compiled, report


def run_cell(arch: str, shape_name: str, mesh, out_dir: Path, *,
             remat: str = "full", use_reduced: bool = False,
             extra_rules=None, cfg_overrides=None, zero: bool = False) -> dict:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    if cfg_overrides:
        import dataclasses as _dc
        moe_over = cfg_overrides.pop("capacity_factor", None)
        cfg = _dc.replace(cfg, **cfg_overrides)
        if moe_over is not None and cfg.moe is not None:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                                   capacity_factor=moe_over))
    shape = SHAPES[shape_name]
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    out_path = out_dir / mesh_name / f"{cfg.name}__{shape_name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if not applicable(cfg, shape):
        report = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped",
                  "reason": "long_500k requires sub-quadratic attention "
                            "(DESIGN.md SS Arch-applicability)"}
        out_path.write_text(json.dumps(report, indent=2))
        return report
    try:
        compiled, report = lower_cell(cfg, shape, mesh, remat=remat,
                                      extra_rules=extra_rules, zero=zero)
        del compiled
    except Exception as e:  # a failing cell is a bug; record it loudly
        report = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    out_path.write_text(json.dumps(report, indent=2))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=("full", "dots", "dots_no_batch", "none"))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke mode: reduced configs (CI)")
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. 2,4 with axes data,model")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-shard master params/moments over data")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual streams (seq -> model)")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--rwkv-chunk", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = []
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        meshes.append(make_mesh(shape, axes))
    elif args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_ok = n_skip = n_err = 0
    for mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                t0 = time.time()
                extra_rules = {"seq": "model"} if args.sp else None
                overrides = {}
                if args.capacity_factor is not None:
                    overrides["capacity_factor"] = args.capacity_factor
                if args.rwkv_chunk is not None:
                    overrides["rwkv_chunk"] = args.rwkv_chunk
                rep = run_cell(arch, shape_name, mesh, out_dir,
                               remat=args.remat, use_reduced=args.reduced,
                               extra_rules=extra_rules,
                               cfg_overrides=overrides or None,
                               zero=args.zero)
                dt = time.time() - t0
                status = rep["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                line = (f"[{status:7s}] {rep['mesh']:9s} {arch:22s} "
                        f"{shape_name:12s} {dt:7.1f}s")
                if status == "ok":
                    r = rep["roofline"]
                    line += (f"  flops/dev={r['flops_per_device']:.3e}"
                             f" Tc={r['t_compute']:.4f}s Tm={r['t_memory']:.4f}s"
                             f" Tx={r['t_collective']:.4f}s -> {r['bottleneck']}")
                elif status == "error":
                    line += "  " + rep["error"][:160]
                print(line, flush=True)
    print(f"DONE ok={n_ok} skipped={n_skip} errors={n_err}", flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
