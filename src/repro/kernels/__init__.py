"""Pallas TPU kernels for the compute hot-spots (+ ops.py wrappers,
ref.py oracles): episode_track (the paper's parallel local tracking),
flash_attention, wkv_chunk. All validated in interpret mode on CPU;
BlockSpec tiling targets TPU VMEM. autotune resolves per-bucket tile
configs (tuned_configs.json) for the tracking/count launches."""
from . import autotune, episode_track, flash_attention, ops, ref, wkv_chunk

__all__ = ["autotune", "episode_track", "flash_attention", "ops", "ref",
           "wkv_chunk"]
