"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -jnp.inf


def track_level_ref(t_prev, v_prev, t_next, t_low, t_high) -> jax.Array:
    """Quadratic masked max — independent oracle for episode_track.

    v_next[a] = max over {b : t_next[a]-hi <= t_prev[b] < t_next[a]-lo}
    of v_prev[b]; -inf when empty.
    """
    ok = (t_prev[None, :] >= t_next[:, None] - t_high) & (
        t_prev[None, :] < t_next[:, None] - t_low)
    return jnp.max(jnp.where(ok, v_prev[None, :], NEG), axis=1)


def track_episode_ref(times_by_sym, t_low, t_high):
    """Full multi-level tracking using the quadratic oracle per level.

    Returns (starts, ends) with -inf/+inf padding, matching
    core.tracking.track_dense semantics.
    """
    n = times_by_sym.shape[0]
    t0 = times_by_sym[0]
    v = jnp.where(jnp.isfinite(t0), t0, NEG)
    for i in range(n - 1):
        v = track_level_ref(times_by_sym[i], v, times_by_sym[i + 1],
                            t_low[i], t_high[i])
        v = jnp.where(jnp.isfinite(times_by_sym[i + 1]), v, NEG)
    ends = times_by_sym[n - 1]
    valid = (v > NEG) & jnp.isfinite(ends)
    return jnp.where(valid, v, NEG), jnp.where(valid, ends, jnp.inf)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Plain softmax attention oracle. q,k,v: [seq, heads, dim] (q heads may
    be a multiple of kv heads — GQA)."""
    sq, hq, d = q.shape
    sk, hk, _ = k.shape
    group = hq // hk
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask[None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv_sequential_ref(r, k, v, logw, u):
    """Sequential oracle for the WKV recurrence (kernel contract):
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    r/k/v/logw: [b, T, h, hd]; u: [h, hd]. Returns o [b, T, h, hd]."""
    b, t, h, hd = r.shape
    rf = r.astype(jnp.float32); kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32); w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    s = jnp.zeros((b, h, hd, hd), jnp.float32)
    outs = []
    for i in range(t):
        cur = s + (uf[None] * kf[:, i])[..., None] * vf[:, i][:, :, None, :]
        outs.append(jnp.einsum("bhi,bhiv->bhv", rf[:, i], cur))
        s = w[:, i][..., None] * s + kf[:, i][..., None] * vf[:, i][:, :, None, :]
    return jnp.stack(outs, axis=1)
