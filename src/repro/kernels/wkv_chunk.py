"""Pallas TPU kernel for chunked WKV (RWKV6-family gated linear recurrence).

The third member of this repo's scan-transformation family (with
episode_track and flash_attention): the sequential per-token recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;   o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

becomes, per (batch, head), a grid walk over chunks of L tokens whose
[hd, hd] state lives in VMEM scratch across grid steps; inside a chunk the
pairwise term is an (L, L) masked matmul with per-channel decay factors
(all exponents <= 0 by construction — see models/rwkv6.py for the
normalizer algebra). One kernel invocation = whole sequence; HBM traffic is
exactly one read of r/k/v/w and one write of o.

VMEM per step @ L=128, hd=64 fp32: 4 chunk tiles + scores + state
~= 4*32 KB + 64 KB + 16 KB ~= 0.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)          # [L, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)        # log decay, <= 0
    u = u_ref[0, :]                                    # [hd]

    bcum = jnp.cumsum(lw, axis=0)                      # inclusive
    bex = bcum - lw                                    # exclusive (b_{t-1})
    btot = bcum[-1]                                    # [hd]

    qp = r * jnp.exp(bex - btot[None, :])              # exponents >= 0, bounded
    kp = k * jnp.exp(btot[None, :] - bcum)             # exponents <= 0
    scores = jnp.dot(qp, kp.T, preferred_element_type=jnp.float32)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(lj < li, scores, 0.0)           # strict causal in-chunk
    o = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    # current-token bonus
    o = o + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    # carry-in state contribution and state update
    s = s_ref[...]
    o = o + jnp.dot(r * jnp.exp(bex), s, preferred_element_type=jnp.float32)
    kv = jnp.dot(kp.T, v, preferred_element_type=jnp.float32)   # [hd, hd]
    s_ref[...] = jnp.exp(btot)[:, None] * s + kv
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(r, k, v, logw, u, *, chunk: int = 64,
                interpret: bool = False):
    """r/k/v/logw: [b, T, h, hd] (logw <= 0); u: [h, hd]. Returns o
    [b, T, h, hd] (pre-receptance-gate WKV output)."""
    b, t, h, hd = r.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    grid = (b, h, t // c)
    spec = pl.BlockSpec((1, c, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0))
    kernel = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=c),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda bi, hi, ci: (hi, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )
    return kernel(r, k, v, logw, u)
