"""Per-bucket tile autotuning for the Pallas tracking / count kernels.

The fused kernels expose three tile knobs (``block_next`` / ``block_prev`` /
``window_tiles``) plus the count pipeline's ``chunk`` (episode rows per grid
step). The best setting depends on the problem *bucket* — episode length L,
capacity N and batch B — not on a global constant: small streams want tiny
tiles (less boundary slack per constraint window, more of the latest-start
row resident per step), large batches amortize per-grid-step overhead with
bigger chunks.

This module is the single source of truth for that resolution:

* :func:`bucket_key` — ``"kind:L{L}:N{pow2}:B{pow2}"`` buckets (capacity and
  batch rounded up to powers of two so nearby shapes share an entry).
* :func:`resolve` — explicit caller overrides > checked-in
  ``tuned_configs.json`` entry > :data:`DEFAULTS`. Pure function of its
  arguments and the table file: deterministic, trace-time cheap (dict
  lookup), safe to call from inside ``jit`` with static shapes.
* :func:`candidate_configs` / :func:`model_time` — the tuning search space
  and the cost-model filter. ``model_time`` routes an analytic byte/flop
  estimate through :func:`analysis.roofline.analyze` (plus a per-grid-step
  launch-overhead term the roofline cannot see); ``benchmarks/run.py
  --autotune`` uses it to pre-rank candidates, wall-clocks the survivors,
  and regenerates ``tuned_configs.json`` — wiring the previously write-only
  roofline / hlo_costs models into the hot path.

Every counting/mining entry point resolves ``None`` block knobs through
:func:`resolve`, so ``count_batch_indexed``, ``mine_corpus``,
``mine_sharded`` and ``StreamingMiner`` all inherit tuned tiles without any
signature churn; passing explicit integers keeps the exact legacy behavior.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Dict, List, Optional

from ..analysis import roofline
# THE shared rounding rule: plan buckets and tuned-tile buckets round with
# the same function (core/plan.py owns it), so a MiningPlan's capacity
# classes and this module's bucket_key can never diverge — regression-tested
# in tests/test_plan_cache.py against every checked-in tuned_configs entry.
from ..core.plan import pow2_ceil

_CONFIG_PATH = os.path.join(os.path.dirname(__file__), "tuned_configs.json")

# Per-grid-step overhead (s): pallas_call grid sequencing / interpret-mode
# loop step. Dominates tiny cells; the roofline terms dominate large ones.
_STEP_OVERHEAD_S = 15e-6
# Constraint-window span assumed by the analytic model when the true event
# density is unknown at resolve time (fraction of the capacity).
_SPAN_FRACTION = 0.05


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One bucket's tile/grid shape for a Pallas kernel launch."""
    block_next: int = 256
    block_prev: int = 256
    window_tiles: int = 0
    chunk: int = 8

    def asdict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


#: Fallbacks when no tuned entry exists — the pre-autotune constants, so a
#: missing/deleted tuned_configs.json reproduces legacy behavior exactly.
DEFAULTS: Dict[str, TileConfig] = {
    "track": TileConfig(block_next=256, block_prev=256, window_tiles=0, chunk=8),
    "count": TileConfig(block_next=256, block_prev=256, window_tiles=0, chunk=8),
}


# back-compat alias: callers/tests that reached for the private name keep
# working; the one definition lives in core/plan.py
_pow2_ceil = pow2_ceil


def bucket_key(kind: str, levels: int, cap: int, batch: int) -> str:
    """Deterministic bucket id for a (kernel kind, L, N, B) problem shape.

    Idempotent under the rounding rule: ``bucket_key(kind, L,
    pow2_ceil(cap), pow2_ceil(batch)) == bucket_key(kind, L, cap, batch)``
    — which is what lets ``plan_for`` round shapes *first* and still
    resolve the same tuned tiles the raw shapes would.
    """
    if kind not in DEFAULTS:
        raise ValueError(
            f"unknown kernel kind {kind!r}; expected one of {sorted(DEFAULTS)}")
    return f"{kind}:L{int(levels)}:N{pow2_ceil(cap)}:B{pow2_ceil(batch)}"


@functools.lru_cache(maxsize=None)
def _load_table(path: str) -> Dict[str, Dict[str, int]]:
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return {}
    table = raw.get("configs", raw) if isinstance(raw, dict) else {}
    return {k: v for k, v in table.items() if isinstance(v, dict)}


def load_table(path: Optional[str] = None) -> Dict[str, Dict[str, int]]:
    """The checked-in tuned table ({} when missing/invalid — never raises)."""
    return dict(_load_table(path or _CONFIG_PATH))


def clear_cache() -> None:
    """Drop the memoized table (tests / post-``--autotune`` regeneration)."""
    _load_table.cache_clear()


def resolve(
    kind: str,
    levels: int,
    cap: int,
    batch: int,
    *,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    chunk: Optional[int] = None,
    path: Optional[str] = None,
) -> TileConfig:
    """Tile config for a problem bucket.

    Precedence per field: explicit (non-None) caller override, then the
    tuned-table entry for :func:`bucket_key`, then :data:`DEFAULTS[kind]`.
    Deterministic: same arguments + same table file => same answer.
    """
    base = DEFAULTS[kind] if kind in DEFAULTS else None
    key = bucket_key(kind, levels, cap, batch)   # validates kind
    entry = _load_table(path or _CONFIG_PATH).get(key, {})

    def pick(override, field):
        if override is not None:
            return int(override)
        return int(entry.get(field, getattr(base, field)))

    return TileConfig(
        block_next=pick(block_next, "block_next"),
        block_prev=pick(block_prev, "block_prev"),
        window_tiles=pick(window_tiles, "window_tiles"),
        chunk=pick(chunk, "chunk"),
    )


# ---------------------------------------------------------------------------
# Tuning search space + roofline-backed cost model
# ---------------------------------------------------------------------------


def candidate_configs(kind: str, cap: int, batch: int) -> List[TileConfig]:
    """Deterministic candidate grid for one bucket (exact-tiling configs
    only; ``window_tiles`` stays 0 — exactness is non-negotiable)."""
    if kind not in DEFAULTS:
        raise ValueError(
            f"unknown kernel kind {kind!r}; expected one of {sorted(DEFAULTS)}")
    blocks = [b for b in (8, 16, 32, 64, 128, 256) if b <= cap]
    chunks = [c for c in (8, 16, 32) if c <= max(batch, 8)]
    out = []
    for b in blocks:
        for c in (chunks if kind == "count" else [DEFAULTS[kind].chunk]):
            out.append(TileConfig(block_next=b, block_prev=b,
                                  window_tiles=0, chunk=c))
    return out


def model_cost(
    kind: str, levels: int, cap: int, batch: int, cfg: TileConfig,
) -> Dict[str, float]:
    """Analytic per-launch cost estimate, in the cost-dict dialect
    ``hlo_costs.module_costs`` / ``roofline.analyze`` speak
    (``flops`` + ``"bytes accessed"``), plus the grid step count."""
    bn, bp = cfg.block_next, cfg.block_prev
    next_tiles = max(1, cap // max(bn, 1))
    # prev events each next event's window is assumed to span, plus the two
    # boundary tiles of misalignment slack the scan table always includes
    span = _SPAN_FRACTION * cap + 2 * bp
    tiles = max(1.0, span / max(bp, 1))
    pair_ops = batch * levels * cap * tiles * bp     # (next, prev) compares
    if kind == "count":
        steps = -(-batch // max(cfg.chunk, 1))
        # compaction prefix-scan + searchsorted gather + greedy fold
        epilogue = batch * cap * 8.0
    else:
        steps = batch * levels * next_tiles * tiles
        epilogue = 0.0
    return {
        "flops": 4.0 * pair_ops + epilogue,
        "bytes accessed": 8.0 * pair_ops + 4.0 * epilogue,
        "grid_steps": float(steps),
    }


def model_time(
    kind: str, levels: int, cap: int, batch: int, cfg: TileConfig,
) -> float:
    """Modelled launch latency (s): roofline compute/memory terms + the
    per-grid-step overhead the roofline cannot express."""
    cost = model_cost(kind, levels, cap, batch, cfg)
    r = roofline.analyze(
        arch="v5e", shape=f"{kind}:L{levels}:N{cap}:B{batch}",
        mesh_name="single", chips=1, cost=cost, coll={"total": 0.0},
        model_flops=0.0)
    return max(r.t_compute, r.t_memory) + cost["grid_steps"] * _STEP_OVERHEAD_S


def rank_candidates(
    kind: str, levels: int, cap: int, batch: int, top_k: int = 4,
) -> List[TileConfig]:
    """Model-ranked candidate shortlist for wall-clock confirmation."""
    cands = candidate_configs(kind, cap, batch)
    scored = sorted(cands, key=lambda c: (
        model_time(kind, levels, cap, batch, c),
        c.block_next, c.block_prev, c.chunk))
    return scored[: max(1, top_k)]
