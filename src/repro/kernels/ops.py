"""jit'd public wrappers around the Pallas kernels (+ exactness bounds).

Two entry points drive ``episode_track``:

* :func:`track_level` — one tracking level, one ``pallas_call``. Arrays of
  any capacity are accepted: they are padded up to a tile multiple (+inf
  times / -inf values — a max-accumulation no-op) instead of degrading the
  block sizes to a divisor of the capacity.
* :func:`track_batch` — the fused batched path: a whole ``[B, N, cap]``
  candidate batch's multi-level tracking in ONE launch, with the
  per-(episode, level, next-tile) scan table precomputed here (the paper's
  per-type index made block-level, batched) and window-cap truncation
  *flagged, never silent*.

The window-span math (`searchsorted` over next-tile extrema) is shared by
the static host-side bounds (:func:`required_window_tiles`,
:func:`required_window_tiles_batch`) and the traced batched precompute
(:func:`window_scan_table`) through :func:`_tile_spans`.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import episode_track as _et
from . import ref as _ref

NEG = -jnp.inf

# Interpret-mode (off-TPU) batching granularity for the fused kernel: the
# interpret grid loop carries the full operand buffers through a
# lax.while_loop and writes blocks back each step, which costs
# O(grid_steps x batch_buffer) — quadratic in the batch size. Mapping over
# fixed-size chunks keeps the emulation linear; on real TPUs the kernel is
# launched once for the whole batch and this constant is irrelevant.
_INTERPRET_BATCH_CHUNK = 8


# ---------------------------------------------------------------------------
# Window-span bounds (shared: host bounds + traced fused precompute)
# ---------------------------------------------------------------------------


def _searchsorted_rows(a: jax.Array, v: jax.Array) -> jax.Array:
    """Row-wise ``searchsorted(a[..., :], v[..., :], 'left')`` over any
    (shared) leading batch dims."""
    if a.ndim == 1:
        return jnp.searchsorted(a, v, side="left")
    flat_a = a.reshape(-1, a.shape[-1])
    flat_v = v.reshape(-1, v.shape[-1])
    out = jax.vmap(lambda x, y: jnp.searchsorted(x, y, side="left"))(
        flat_a, flat_v)
    return out.reshape(v.shape)


def _tile_spans(
    t_prev: jax.Array,   # f32[..., cap] sorted rows, +inf padded
    t_next: jax.Array,   # f32[..., cap] sorted rows, +inf padded
    t_high,              # f32[...] (or scalar) per-row window high
    block_next: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per next-tile prev-event span ``[lo_idx, hi_idx)`` + occupancy mask.

    Spans are *event* indices — callers quantize to prev tiles themselves,
    so ``block_prev`` never enters this computation.

    A next tile with min ``a0`` / finite max ``a1`` needs prev events in
    ``[a0 - t_high, a1)``; rows are sorted so the tile min is element 0 and
    padding tiles (min = +inf) report ``has = False``. Returns
    ``(lo_idx, hi_idx, has)`` each shaped ``[..., next_tiles]``.
    """
    nt = t_next.shape[-1] // block_next
    tiles = t_next[..., : nt * block_next].reshape(
        t_next.shape[:-1] + (nt, block_next))
    tile_min = tiles[..., 0]
    finite = jnp.isfinite(tiles)
    tile_max = jnp.max(jnp.where(finite, tiles, -jnp.inf), axis=-1)
    has = finite[..., 0]
    t_high = jnp.asarray(t_high, jnp.float32)[..., None]
    lo_idx = _searchsorted_rows(t_prev, tile_min - t_high)
    hi_idx = _searchsorted_rows(t_prev, tile_max)
    return lo_idx.astype(jnp.int32), hi_idx.astype(jnp.int32), has


def required_window_tiles(
    t_prev: np.ndarray, t_next: np.ndarray, t_high: float,
    block_next: int, block_prev: int,
) -> int:
    """Host-side tight bound on prev tiles any next tile's window can span.

    Vectorized (reshape + one searchsorted per side) twin of the old
    per-tile Python loop: span in events plus one tile of misalignment
    slack, maxed over occupied next tiles.
    """
    t_prev = np.asarray(t_prev)
    t_next = np.asarray(t_next)
    cap = t_prev.shape[0]
    lo_idx, hi_idx, has = (np.asarray(x) for x in _tile_spans(
        t_prev, t_next, float(t_high), block_next))
    spans = np.where(has, hi_idx - lo_idx, 0)
    tiles = int(np.max(spans // block_prev + 2, initial=1, where=has))
    return min(max(tiles, 1), cap // block_prev)


def required_window_tiles_batch(
    times_by_sym: np.ndarray,   # f32[B, N, cap] sorted rows, +inf padded
    t_high: np.ndarray,         # f32[B, N-1]
    block_next: int, block_prev: int,
) -> int:
    """Batched :func:`required_window_tiles`: one static bound covering
    every (episode, level) of a candidate batch — callers use it to pick a
    ``window_tiles`` cap that keeps the fused kernel exact."""
    times_by_sym = np.asarray(times_by_sym)
    cap = times_by_sym.shape[-1]
    lo_idx, hi_idx, has = (np.asarray(x) for x in _tile_spans(
        times_by_sym[:, :-1], times_by_sym[:, 1:], np.asarray(t_high),
        block_next))
    spans = np.where(has, hi_idx - lo_idx, 0)
    tiles = int(np.max(spans // block_prev + 2, initial=1, where=has))
    return min(max(tiles, 1), cap // block_prev)


def window_span_exceeds(
    lo_idx: jax.Array, hi_idx: jax.Array, cap: int,
    block_prev: int, window_tiles: int,
) -> jax.Array:
    """THE conservative truncation predicate (span + one tile of
    misalignment slack over the cap), shared by the per-level engine's
    check and the fused precompute so their overflow flags cannot drift."""
    span = jnp.clip(hi_idx - lo_idx, 0, cap)
    return span // block_prev + 2 > window_tiles


def window_truncated(
    t_prev: jax.Array,   # f32[cap] sorted, +inf padded
    t_next: jax.Array,   # f32[cap] sorted, +inf padded
    t_high,
    block_next: int, block_prev: int, window_tiles: int,
) -> jax.Array:
    """Traced per-level truncation flag: may any next tile's constraint
    window span more than ``window_tiles`` prev tiles?"""
    cap = t_prev.shape[-1]
    lo_idx, hi_idx, _ = _tile_spans(
        t_prev, t_next, t_high, block_next)
    return jnp.any(window_span_exceeds(
        lo_idx, hi_idx, cap, block_prev, window_tiles))


def window_scan_table(
    times_by_sym: jax.Array,    # f32[B, N, cap] sorted rows, +inf padded
    t_high: jax.Array,          # f32[B, N-1]
    block_next: int,
    block_prev: int,
    window_tiles: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Traced per-(episode, level, next-tile) scan table for the fused kernel.

    Returns ``(start_tile, num_tiles, truncated)``: the first prev tile and
    exact tile count each next tile must scan (both ``i32[B, N-1, NT]``) and
    a per-episode ``bool[B]`` truncation flag. With ``window_tiles > 0`` the
    scan lengths are capped and any episode whose conservative span bound
    (``span // BP + 2``, the same formula the per-level engine checks) may
    exceed the cap is flagged — capping is *reported*, never silent.
    """
    cap = times_by_sym.shape[-1]
    prev_tiles = cap // block_prev
    lo_idx, hi_idx, has = _tile_spans(
        times_by_sym[:, :-1], times_by_sym[:, 1:], t_high, block_next)
    start = lo_idx // block_prev
    end = (hi_idx + block_prev - 1) // block_prev
    num = jnp.where(has, jnp.maximum(end - start, 0), 0)
    if 0 < window_tiles < prev_tiles:
        truncated = jnp.any(window_span_exceeds(
            lo_idx, hi_idx, cap, block_prev, window_tiles), axis=(1, 2))
        num = jnp.minimum(num, window_tiles)
    else:
        truncated = jnp.zeros((times_by_sym.shape[0],), bool)
    start = jnp.clip(start, 0, max(prev_tiles - 1, 0))
    return start.astype(jnp.int32), num.astype(jnp.int32), truncated


# ---------------------------------------------------------------------------
# Tile padding (replaces the old largest-divisor block-size degradation)
# ---------------------------------------------------------------------------


def tile_geometry(cap: int, block_next: int, block_prev: int) -> Tuple[int, int, int]:
    """(bn, bp, padded_cap): the ONE tiling rule every Pallas tracking path
    shares — blocks kept as requested, capacity rounded up to their lcm.
    Padding with +inf times / -inf values is a max-accumulation no-op, so
    tiling efficiency never degrades toward block size 1 for prime or odd
    capacities. The truncation-flag parity between the ``dense_pallas`` and
    ``dense_pallas_fused`` engines depends on this rule being
    single-sourced (tracking._pallas_tile_geometry delegates here)."""
    bn = max(1, block_next)
    bp = max(1, block_prev)
    tile = math.lcm(bn, bp)
    pcap = ((cap + tile - 1) // tile) * tile
    return bn, bp, pcap


def _pad_tail(x: jax.Array, pcap: int, fill) -> jax.Array:
    cap = x.shape[-1]
    if pcap == cap:
        return x
    pad = jnp.full(x.shape[:-1] + (pcap - cap,), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=-1)


# ---------------------------------------------------------------------------
# Per-level kernel wrapper
# ---------------------------------------------------------------------------


def track_level(
    t_prev: jax.Array,
    v_prev: jax.Array,
    t_next: jax.Array,
    t_low: float,
    t_high: float,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One tracking level; Pallas kernel on TPU, oracle fallback elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return _ref.track_level_ref(t_prev, v_prev, t_next, t_low, t_high)
    cap = t_prev.shape[0]
    bn, bp, pcap = tile_geometry(cap, block_next, block_prev)
    out = _et.track_level_pallas(
        _pad_tail(t_prev, pcap, jnp.inf), _pad_tail(v_prev, pcap, NEG),
        _pad_tail(t_next, pcap, jnp.inf), t_low, t_high,
        block_next=bn, block_prev=bp, window_tiles=window_tiles,
        interpret=interpret)
    return out[:cap]


def track_episode(
    times_by_sym: jax.Array,   # f32[N, cap]
    t_low,
    t_high,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    interpret: Optional[bool] = None,
):
    """Full multi-level tracking through the kernel; returns (starts, ends)."""
    n = times_by_sym.shape[0]
    t0 = times_by_sym[0]
    v = jnp.where(jnp.isfinite(t0), t0, NEG)
    lows = np.asarray(t_low, np.float32).reshape(-1)
    highs = np.asarray(t_high, np.float32).reshape(-1)
    for i in range(n - 1):
        v = track_level(
            times_by_sym[i], v, times_by_sym[i + 1],
            float(lows[i]), float(highs[i]),
            block_next=block_next, block_prev=block_prev,
            window_tiles=window_tiles, interpret=interpret)
        v = jnp.where(jnp.isfinite(times_by_sym[i + 1]), v, NEG)
    ends = times_by_sym[n - 1]
    valid = (v > NEG) & jnp.isfinite(ends)
    return jnp.where(valid, v, NEG), jnp.where(valid, ends, jnp.inf)


# ---------------------------------------------------------------------------
# Fused batched multi-level wrapper
# ---------------------------------------------------------------------------


def track_batch(
    times_by_sym: jax.Array,    # f32[..., N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[..., N-1]
    t_high: jax.Array,          # f32[..., N-1]
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Whole candidate batch, all levels, one fused Pallas launch.

    Returns ``(starts f32[B, cap], n_superset i32[B], truncated bool[B])``.
    ``starts`` holds the final-level latest-start values (-inf where no
    occurrence ends at that event); validity masking against the last
    symbol's times is the caller's (engine's) job, mirroring
    ``track_episode``. ``window_tiles`` caps the per-tile scan length for a
    latency bound — possible truncation is flagged, never silent.

    Stream axis: stacked leading dims — a ``[S, B, N, cap]`` corpus of
    ``S`` streams by ``B`` episodes — fold into the kernel's batch grid
    dimension here (THE one fold; per-row scan tables are row-independent,
    so the flattened layout is fold-invariant) and unfold on the way out.
    One corpus, one launch.
    """
    lead = times_by_sym.shape[:-2]
    if len(lead) > 1:
        rows = math.prod(lead)
        starts, nsup, truncated = track_batch(
            times_by_sym.reshape((rows,) + times_by_sym.shape[-2:]),
            t_low.reshape((rows,) + t_low.shape[-1:]),
            t_high.reshape((rows,) + t_high.shape[-1:]),
            block_next=block_next, block_prev=block_prev,
            window_tiles=window_tiles, interpret=interpret)
        return (starts.reshape(lead + starts.shape[-1:]),
                nsup.reshape(lead), truncated.reshape(lead))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batch, n, cap = times_by_sym.shape
    t0 = times_by_sym[:, 0, :]
    if n == 1:  # no transitions: every first-symbol event is an occurrence
        starts = jnp.where(jnp.isfinite(t0), t0, NEG)
        nsup = jnp.sum(jnp.isfinite(t0), axis=-1).astype(jnp.int32)
        return starts, nsup, jnp.zeros((batch,), bool)
    bn, bp, pcap = tile_geometry(cap, block_next, block_prev)
    padded = _pad_tail(times_by_sym, pcap, jnp.inf)
    start_tile, num_tiles, truncated = window_scan_table(
        padded, t_high, bn, bp, window_tiles)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    chunk = _INTERPRET_BATCH_CHUNK
    if interpret and batch > chunk:
        nchunks = -(-batch // chunk)
        pad_rows = nchunks * chunk - batch

        def chunked(x, fill):
            if pad_rows:   # all-padding rows scan zero tiles: a no-op
                x = jnp.concatenate(
                    [x, jnp.full((pad_rows,) + x.shape[1:], fill, x.dtype)])
            return x.reshape((nchunks, chunk) + x.shape[1:])

        starts, nsup = jax.lax.map(
            lambda xs: _et.track_batch_pallas(
                *xs, block_next=bn, block_prev=bp, interpret=True),
            (chunked(padded, jnp.inf), chunked(t_low, 0), chunked(t_high, 0),
             chunked(start_tile, 0), chunked(num_tiles, 0)))
        starts = starts.reshape(nchunks * chunk, pcap)[:batch]
        nsup = nsup.reshape(-1)[:batch]
    else:
        starts, nsup = _et.track_batch_pallas(
            padded, t_low, t_high, start_tile, num_tiles,
            block_next=bn, block_prev=bp, interpret=interpret)
    return starts[:, :cap], nsup, truncated


def track_corpus(
    times_by_sym: jax.Array,    # f32[S, B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[B, N-1] shared across streams
    t_high: jax.Array,          # f32[B, N-1]
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """A whole corpus of streams x candidate batch in one fused launch.

    The stream axis folds into the kernel's batch grid dimension —
    ``(stream, episode)`` rows are independent, and each folded row's scan
    offsets come from its own stream's per-type index — so ragged stream
    lengths cost +inf padding inside ``cap``, never extra launches.

    Returns ``(starts f32[S, B, cap], n_superset i32[S, B],
    truncated bool[S, B])``; the per-episode windows are broadcast over the
    stream axis (the corpus miner counts one shared candidate frontier
    against every stream).
    """
    s = times_by_sym.shape[0]
    t_low = jnp.broadcast_to(
        jnp.asarray(t_low, jnp.float32)[None], (s,) + t_low.shape)
    t_high = jnp.broadcast_to(
        jnp.asarray(t_high, jnp.float32)[None], (s,) + t_high.shape)
    return track_batch(
        times_by_sym, t_low, t_high,
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, interpret=interpret)


# ---------------------------------------------------------------------------
# Fused single-launch count pipeline wrapper
# ---------------------------------------------------------------------------


def count_batch(
    times_by_sym: jax.Array,    # f32[..., N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[..., N-1]
    t_high: jax.Array,          # f32[..., N-1]
    prev_end: jax.Array,        # f32[...] carried greedy prev_end
    prev_count: jax.Array,      # i32[...] carried greedy count
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    chunk: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Whole candidate batch, tracking + compaction + greedy, one launch.

    Returns ``(counts i32[B], end_out f32[B], n_superset i32[B],
    truncated bool[B])``. Counts include the ``prev_count`` carry-in and
    ``end_out`` is the carried greedy state, so streaming chain-state
    stitching works exactly as with the track + host-greedy path. The
    per-(episode, level) latest-start tables and occurrence intervals never
    leave VMEM — only these O(B) scalars do. ``chunk`` sets how many episode
    rows each grid step owns (the count-kernel analogue of the track path's
    interpret chunking; on TPU it bounds per-step VMEM).

    Stream axis: stacked leading dims fold into the kernel's batch grid
    dimension, mirroring :func:`track_batch`.
    """
    lead = times_by_sym.shape[:-2]
    if len(lead) > 1:
        rows = math.prod(lead)
        counts, end_out, nsup, truncated = count_batch(
            times_by_sym.reshape((rows,) + times_by_sym.shape[-2:]),
            t_low.reshape((rows,) + t_low.shape[-1:]),
            t_high.reshape((rows,) + t_high.shape[-1:]),
            prev_end.reshape(rows), prev_count.reshape(rows),
            block_next=block_next, block_prev=block_prev,
            window_tiles=window_tiles, chunk=chunk, interpret=interpret)
        return (counts.reshape(lead), end_out.reshape(lead),
                nsup.reshape(lead), truncated.reshape(lead))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batch, n, cap = times_by_sym.shape
    prev_end = jnp.asarray(prev_end, jnp.float32)
    prev_count = jnp.asarray(prev_count, jnp.int32)
    if n == 1:
        # No transitions: every first-symbol event is a [t, t] occurrence.
        # Greedy over sorted point intervals takes each finite time strictly
        # greater than both the running prev_end and its predecessor (ties
        # rejected, matching greedy_scan_state's strict `start > prev_end`).
        t0 = times_by_sym[:, 0, :]
        finite = jnp.isfinite(t0)
        pred = jnp.concatenate(
            [jnp.full((batch, 1), NEG, t0.dtype), t0[:, :-1]], axis=1)
        take = finite & (t0 > prev_end[:, None]) & (t0 > pred)
        cnt = jnp.sum(take, axis=1).astype(jnp.int32)
        last = jnp.max(jnp.where(take, t0, NEG), axis=1)
        end_out = jnp.where(cnt > 0, last, prev_end)
        nsup = jnp.sum(finite, axis=-1).astype(jnp.int32)
        return prev_count + cnt, end_out, nsup, jnp.zeros((batch,), bool)
    bn, bp, pcap = tile_geometry(cap, block_next, block_prev)
    padded = _pad_tail(times_by_sym, pcap, jnp.inf)
    start_tile, num_tiles, truncated = window_scan_table(
        padded, t_high, bn, bp, window_tiles)
    counts, end_out, nsup = _et.count_batch_pallas(
        padded, jnp.asarray(t_low, jnp.float32),
        jnp.asarray(t_high, jnp.float32), start_tile, num_tiles,
        prev_end, prev_count,
        block_next=bn, block_prev=bp, chunk=chunk, interpret=interpret)
    return counts, end_out, nsup, truncated


def count_corpus(
    times_by_sym: jax.Array,    # f32[S, B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[B, N-1] shared across streams
    t_high: jax.Array,          # f32[B, N-1]
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    chunk: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Corpus count: streams x episodes folded into one fused count launch.

    Fresh (-inf, 0) carries per (stream, episode) row — corpus counting is
    stateless. Returns ``(counts i32[S, B], end_out f32[S, B],
    n_superset i32[S, B], truncated bool[S, B])``.
    """
    s, b = times_by_sym.shape[0], times_by_sym.shape[1]
    t_low = jnp.broadcast_to(
        jnp.asarray(t_low, jnp.float32)[None], (s,) + t_low.shape)
    t_high = jnp.broadcast_to(
        jnp.asarray(t_high, jnp.float32)[None], (s,) + t_high.shape)
    return count_batch(
        times_by_sym, t_low, t_high,
        jnp.full((s, b), NEG, jnp.float32), jnp.zeros((s, b), jnp.int32),
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, chunk=chunk, interpret=interpret)
