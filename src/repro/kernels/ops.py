"""jit'd public wrappers around the Pallas kernels (+ exactness bounds)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import episode_track as _et
from . import ref as _ref

NEG = -jnp.inf


def required_window_tiles(
    t_prev: np.ndarray, t_next: np.ndarray, t_high: float,
    block_next: int, block_prev: int,
) -> int:
    """Host-side tight bound on prev tiles any next tile's window can span.

    A next tile [a0, a1] needs prev events in [a0 - t_high, a1); the kernel
    starts at tile(searchsorted(a0 - t_high)) so the span in events is
    searchsorted(a1^-) - searchsorted(a0 - t_high), plus one tile of
    misalignment slack.
    """
    t_prev = np.asarray(t_prev)
    t_next = np.asarray(t_next)
    cap = t_prev.shape[0]
    nt = cap // block_next
    tiles = 1
    for i in range(nt):
        blk = t_next[i * block_next:(i + 1) * block_next]
        finite = blk[np.isfinite(blk)]
        if finite.size == 0:
            continue
        lo_i = np.searchsorted(t_prev, finite.min() - t_high, side="left")
        hi_i = np.searchsorted(t_prev, finite.max(), side="left")
        span = int(hi_i - lo_i)
        tiles = max(tiles, span // block_prev + 2)
    return min(tiles, cap // block_prev)


def track_level(
    t_prev: jax.Array,
    v_prev: jax.Array,
    t_next: jax.Array,
    t_low: float,
    t_high: float,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One tracking level; Pallas kernel on TPU, oracle fallback elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return _ref.track_level_ref(t_prev, v_prev, t_next, t_low, t_high)
    cap = t_prev.shape[0]
    bn = _largest_divisor_block(cap, block_next)
    bp = _largest_divisor_block(cap, block_prev)
    return _et.track_level_pallas(
        t_prev, v_prev, t_next, t_low, t_high,
        block_next=bn, block_prev=bp, window_tiles=window_tiles,
        interpret=interpret)


def _largest_divisor_block(cap: int, want: int) -> int:
    b = min(want, cap)
    while cap % b:
        b -= 1
    return max(b, 1)


def track_episode(
    times_by_sym: jax.Array,   # f32[N, cap]
    t_low,
    t_high,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,
    interpret: Optional[bool] = None,
):
    """Full multi-level tracking through the kernel; returns (starts, ends)."""
    n = times_by_sym.shape[0]
    t0 = times_by_sym[0]
    v = jnp.where(jnp.isfinite(t0), t0, NEG)
    lows = np.asarray(t_low, np.float32).reshape(-1)
    highs = np.asarray(t_high, np.float32).reshape(-1)
    for i in range(n - 1):
        v = track_level(
            times_by_sym[i], v, times_by_sym[i + 1],
            float(lows[i]), float(highs[i]),
            block_next=block_next, block_prev=block_prev,
            window_tiles=window_tiles, interpret=interpret)
        v = jnp.where(jnp.isfinite(times_by_sym[i + 1]), v, NEG)
    ends = times_by_sym[n - 1]
    valid = (v > NEG) & jnp.isfinite(ends)
    return jnp.where(valid, v, NEG), jnp.where(valid, ends, jnp.inf)
