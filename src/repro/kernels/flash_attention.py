"""Pallas TPU flash attention (forward): hand-tiled VMEM twin of
models/flash.py (which is the XLA-expressible version the dry-run lowers).

Grid: (batch, heads, q_blocks, kv_blocks); the innermost kv dimension
accumulates online-softmax statistics in VMEM scratch (m, l, acc) and the
output block is written on the last kv step. The (BQ, BK) score tile lives
entirely in VMEM — this is precisely the traffic the XLA version must
stream through HBM per chunk (see EXPERIMENTS.md §Perf: flash score
streams dominate command-r's memory term), i.e. the kernel removes the
dominant memory-roofline contributor of attention-heavy cells on real TPU.

VMEM per step @ BQ=BK=512, hd=128, fp32: q/k/v blocks 3*0.26 MB +
scores 1 MB + acc 0.26 MB ~= 2 MB << 16 MB/core.

Causal blocks strictly above the diagonal are skipped with pl.when
(compute, not just masked) — the same causal-skip optimization the XLA
twin implements with a pair-list scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # python float: pallas kernels cannot capture traced constants


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      scale: float, block_q: int, block_kv: int,
                      causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # [BQ, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # [BK, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(kpos <= qpos, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal (no compute, not a mask)
        pl.when(ki * block_kv <= qi * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = False):
    """q/k/v: [b, s, h, hd] (flat heads, matching models/flash layout)."""
    b, s, h, hd = q.shape
    bq = min(block_q, s)
    bk = min(block_kv, s)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} must divide block sizes {bq},{bk}")
    grid = (b, h, s // bq, s // bk)
    kernel = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, scale=hd ** -0.5, block_q=bq,
                          block_kv=bk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )
    return kernel(q, k, v)
