"""Pallas TPU kernel for one level of parallel local tracking.

This is the compute hot-spot the paper optimizes (Algorithm 2): for every
event ``t`` of the next episode symbol, combine (max-reduce) the
latest-start values of all previous-symbol events ``s`` inside the
inter-event window ``t - hi <= s < t - lo``.

TPU adaptation (DESIGN.md §2): instead of one divergent scanning thread per
event (the CUDA formulation), the time axis is tiled into VMEM blocks. The
grid is ``(next_tiles, window_tiles)``; for next-tile ``i`` the inner
dimension walks the ``window_tiles`` previous-symbol tiles that can overlap
its constraint window, starting at a *scalar-prefetched* tile offset
(computed with searchsorted in ops.py — the paper's per-type index made
block-level). Inside the kernel a (BN, BP) broadcast compare + row max
replaces the divergent scan; max-accumulation is idempotent so clamped /
duplicated boundary tiles are harmless.

VMEM per grid step: BN + 2*BP + BN*BP fp32 ≈ 0.27 MB at BN=BP=256 — far
under the ~16 MB/core budget, leaving room for double buffering.

Fused batched variant (DESIGN.md §2): ``track_batch_pallas`` runs an entire
candidate batch's multi-level tracking in ONE launch. Grid = ``(episodes,
levels, next_tiles)``; the latest-start vector never leaves VMEM between
levels (a ``(2, cap)`` double-buffered scratch, flipped per level), the
per-(episode, level, next-tile) first-prev-tile offsets and scan lengths
are scalar-prefetched as one precomputed table, and the window walk is a
*dynamic* ``fori_loop`` over exactly the prev tiles each next tile's
constraint window spans — no static quadratic tile coverage at all.

Single-launch count pipeline (DESIGN.md §10): ``count_batch_pallas`` goes
further — tracking, the paper's §IV-D count_scan_write compaction, AND the
greedy non-overlap scheduler all run inside ONE kernel. Grid =
``(batch_chunks,)``: each grid step owns a whole chunk of episodes, walks
every level with vectorized whole-chunk tile gathers (occurrence intervals
never leave VMEM), prefix-scans the valid flags and compacts the surviving
``(start, end)`` intervals in-register (the scatter-write inverted into a
searchsorted gather — TPU/XLA-friendly either way), then folds the exact
``greedy_scan_state`` recurrence over ONLY the compacted prefix (a dynamic
``fori_loop`` bounded by the per-chunk max valid count, not ``cap``). The
kernel emits final counts plus the carried ``(prev_end, count)`` chain
state, so the streaming stitch works unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _track_level_kernel(
    # scalar-prefetch operands
    start_tile_ref,     # i32[next_tiles] first prev-tile per next-tile
    window_ref,         # f32[2] = (t_low, t_high)
    # array operands
    t_next_ref,         # f32[BN]   block of next-symbol times
    t_prev_ref,         # f32[BP]   block of prev-symbol times
    v_prev_ref,         # f32[BP]   block of prev-symbol latest-start values
    # outputs
    v_next_ref,         # f32[BN]   accumulated latest-start values
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_next_ref[...] = jnp.full_like(v_next_ref, NEG)

    t_lo = window_ref[0]
    t_hi = window_ref[1]
    t_next = t_next_ref[...]                       # [BN]
    t_prev = t_prev_ref[...]                       # [BP]
    v_prev = v_prev_ref[...]                       # [BP]

    # window: t - hi <= s < t - lo   (paper: lo < t - s <= hi)
    ok = (t_prev[None, :] >= t_next[:, None] - t_hi) & (
        t_prev[None, :] < t_next[:, None] - t_lo)          # [BN, BP]
    contrib = jnp.max(jnp.where(ok, v_prev[None, :], NEG), axis=1)
    v_next_ref[...] = jnp.maximum(v_next_ref[...], contrib)


@functools.partial(
    jax.jit,
    static_argnames=("block_next", "block_prev", "window_tiles", "interpret"),
)
def track_level_pallas(
    t_prev: jax.Array,      # f32[cap] sorted, +inf padded
    v_prev: jax.Array,      # f32[cap] latest-start values (-inf pad)
    t_next: jax.Array,      # f32[cap] sorted, +inf padded
    t_low,
    t_high,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,   # 0 => cover all prev tiles (always exact)
    interpret: bool = False,
) -> jax.Array:
    """One tracking level. Exact iff the constraint window of every next
    block fits in ``window_tiles`` prev blocks (0 = all blocks, always
    exact; ops.py computes a tight bound)."""
    cap = t_prev.shape[0]
    if t_next.shape[0] != cap or v_prev.shape[0] != cap:
        raise ValueError("equal-capacity level arrays required")
    bn = min(block_next, cap)
    bp = min(block_prev, cap)
    if cap % bn or cap % bp:
        raise ValueError(f"cap={cap} must be a multiple of block sizes {bn},{bp}")
    next_tiles = cap // bn
    prev_tiles = cap // bp
    wt = prev_tiles if window_tiles == 0 else min(window_tiles, prev_tiles)

    # first prev tile whose block may intersect the earliest window of the
    # next tile:   first s >= min_t(t_next tile) - t_high
    tile_min = t_next.reshape(next_tiles, bn)[:, 0]
    start_idx = jnp.searchsorted(t_prev, tile_min - jnp.float32(t_high), side="left")
    start_tile = jnp.clip(
        (start_idx // bp).astype(jnp.int32), 0, jnp.int32(max(prev_tiles - wt, 0)))
    window = jnp.asarray([t_low, t_high], jnp.float32)

    grid = (next_tiles, wt)
    kernel = pl.pallas_call(
        _track_level_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn,), lambda i, j, st, w: (i,)),
                pl.BlockSpec((bp,), lambda i, j, st, w: (st[i] + j,)),
                pl.BlockSpec((bp,), lambda i, j, st, w: (st[i] + j,)),
            ],
            out_specs=pl.BlockSpec((bn,), lambda i, j, st, w: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.float32),
        interpret=interpret,
    )
    return kernel(start_tile, window, t_next, t_prev, v_prev)


# ---------------------------------------------------------------------------
# Fused batched multi-level kernel
# ---------------------------------------------------------------------------


def _track_batch_kernel(
    # scalar-prefetch operands (flattened tables; shapes are SMEM-friendly 1-D)
    start_ref,          # i32[B*L*NT] first prev tile per (episode, level, next-tile)
    num_ref,            # i32[B*L*NT] prev tiles to scan per (episode, level, next-tile)
    t_low_ref,          # f32[B*L] per-episode, per-level window low
    t_high_ref,         # f32[B*L] per-episode, per-level window high
    # array operands
    t_next_ref,         # f32[1, 1, BN]  next-symbol tile of the current level
    t_prev_ref,         # f32[1, 1, cap] full prev-symbol row (revisited across tiles)
    # outputs
    v_out_ref,          # f32[1, BN]  final-level latest-start values
    nsup_ref,           # i32[1, 1]   per-episode superset-size accumulator
    # scratch
    vbuf,               # f32[2, cap] level-ping-pong latest-start buffer
    *,
    levels: int,
    next_tiles: int,
    block_next: int,
    block_prev: int,
):
    b = pl.program_id(0)
    l = pl.program_id(1)
    i = pl.program_id(2)
    p = jax.lax.rem(l, 2)
    bn, bp = block_next, block_prev

    t_next = t_next_ref[0, 0, :]                               # [BN]
    t_lo = t_low_ref[b * levels + l]
    t_hi = t_high_ref[b * levels + l]
    flat = (b * levels + l) * next_tiles + i
    st = start_ref[flat]
    num = num_ref[flat]
    is_first_level = l == 0

    def scan_tile(j, acc):
        off = (st + j) * bp
        tp = t_prev_ref[0, 0, pl.ds(off, bp)]                  # [BP]
        # level 0 seeds latest-start = the first-symbol event time itself;
        # later levels read the previous level's values from VMEM scratch.
        vp = jnp.where(is_first_level,
                       jnp.where(jnp.isfinite(tp), tp, NEG),
                       vbuf[p, pl.ds(off, bp)])
        ok = (tp[None, :] >= t_next[:, None] - t_hi) & (
            tp[None, :] < t_next[:, None] - t_lo)              # [BN, BP]
        return jnp.maximum(
            acc, jnp.max(jnp.where(ok, vp[None, :], NEG), axis=1))

    acc = jax.lax.fori_loop(
        0, num, scan_tile, jnp.full((bn,), NEG, jnp.float32))
    acc = jnp.where(jnp.isfinite(t_next), acc, NEG)
    vbuf[1 - p, pl.ds(i * bn, bn)] = acc
    # every visit writes; the grid is sequential so the last level's values
    # are what lands in HBM for this (episode, tile) block.
    v_out_ref[0, :] = acc

    # superset size: count of reachable end events, accumulated per level in
    # the revisited (1, 1) output block; seeded with the level-0 event count.
    n0 = jnp.sum(jnp.isfinite(t_prev_ref[0, 0, :])).astype(jnp.int32)
    seed = jnp.where(is_first_level & (i == 0), n0, nsup_ref[0, 0])
    nsup_ref[0, 0] = seed + jnp.sum(acc > NEG).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_next", "block_prev", "interpret"),
)
def track_batch_pallas(
    times_by_sym: jax.Array,    # f32[B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[B, N-1]
    t_high: jax.Array,          # f32[B, N-1]
    start_tile: jax.Array,      # i32[B, N-1, next_tiles] first prev tile to scan
    num_tiles: jax.Array,       # i32[B, N-1, next_tiles] prev tiles to scan
    *,
    block_next: int = 256,
    block_prev: int = 256,
    interpret: bool = False,
) -> tuple:
    """Whole-batch multi-level tracking in one fused launch.

    Returns ``(starts f32[B, cap], n_superset i32[B])``: the final-level
    latest-start values (before end-validity masking) and the per-episode
    tracked superset size. ``start_tile``/``num_tiles`` come from
    ``ops.window_scan_table`` — exact per-tile spans, so the kernel is exact
    whenever the table is uncapped (``ops`` flags any capping).

    The batch dimension of the grid is just "independent rows": a corpus of
    streams rides it by folding ``(stream, episode)`` into ``B`` — the fold
    lives in ``ops.track_batch`` (per-row scan tables are row-independent,
    so the flattened layout is fold-invariant), not here.
    """
    batch, n, cap = times_by_sym.shape
    levels = n - 1
    if levels < 1:
        raise ValueError("need at least a 2-symbol episode for the kernel")
    bn = min(block_next, cap)
    bp = min(block_prev, cap)
    if cap % bn or cap % bp:
        raise ValueError(f"cap={cap} must be a multiple of block sizes {bn},{bp}")
    next_tiles = cap // bn

    kernel = pl.pallas_call(
        functools.partial(
            _track_batch_kernel, levels=levels, next_tiles=next_tiles,
            block_next=bn, block_prev=bp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(batch, levels, next_tiles),
            in_specs=[
                pl.BlockSpec((1, 1, bn), lambda b, l, i, *_: (b, l + 1, i)),
                pl.BlockSpec((1, 1, cap), lambda b, l, i, *_: (b, l, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, bn), lambda b, l, i, *_: (b, i)),
                pl.BlockSpec((1, 1), lambda b, l, i, *_: (b, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((2, cap), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((batch, cap), jnp.float32),
            jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        ],
        interpret=interpret,
    )
    starts, nsup = kernel(
        start_tile.reshape(-1), num_tiles.reshape(-1),
        t_low.reshape(-1).astype(jnp.float32),
        t_high.reshape(-1).astype(jnp.float32),
        times_by_sym, times_by_sym)
    return starts, nsup[:, 0]


# ---------------------------------------------------------------------------
# Single-launch count pipeline: tracking + compaction + greedy in one kernel
# ---------------------------------------------------------------------------


def _count_batch_kernel(
    # array operands (one grid step owns a whole chunk of R episodes)
    times_ref,          # f32[R, N, cap]   sorted rows, +inf padded
    t_low_ref,          # f32[R, L]        per-episode, per-level window low
    t_high_ref,         # f32[R, L]        per-episode, per-level window high
    start_ref,          # i32[R, L, NT]    first prev tile per next-tile
    num_ref,            # i32[R, L, NT]    prev tiles to scan per next-tile
    pend_ref,           # f32[R, 1]        carried greedy prev_end
    pcnt_ref,           # i32[R, 1]        carried greedy count
    # outputs
    count_ref,          # i32[R, 1]        final non-overlapped counts
    end_ref,            # f32[R, 1]        carried-out prev_end
    nsup_ref,           # i32[R, 1]        tracked superset sizes
    *,
    levels: int,
    block_next: int,
    block_prev: int,
    next_tiles: int,
):
    R = times_ref.shape[0]
    cap = times_ref.shape[2]
    bn, bp = block_next, block_prev

    # --- tracking: the _track_batch_kernel recurrence, vectorized over the
    # whole chunk. The latest-start vector v lives in registers/VMEM for the
    # entire level walk — it is never written back to HBM.
    t0 = times_ref[:, 0, :]
    v = jnp.where(jnp.isfinite(t0), t0, NEG)
    nsup = jnp.sum(jnp.isfinite(t0), axis=-1).astype(jnp.int32)
    bidx = jnp.arange(bp, dtype=jnp.int32)
    for l in range(levels):
        t_next = times_ref[:, l + 1, :]
        tn = t_next.reshape(R, next_tiles, bn)
        st = start_ref[:, l, :]
        num = num_ref[:, l, :]
        t_lo = t_low_ref[:, l][:, None, None, None]
        t_hi = t_high_ref[:, l][:, None, None, None]
        max_num = jnp.max(num)
        t_prev = times_ref[:, l, :]
        vprev = v

        def scan_tile(j, acc, st=st, num=num, t_prev=t_prev, vprev=vprev,
                      tn=tn, t_lo=t_lo, t_hi=t_hi):
            live = j < num                                     # [R, NT]
            idx = (st + j)[:, :, None] * bp + bidx[None, None, :]
            flat = jnp.minimum(idx, cap - 1).reshape(R, -1)
            tp = jnp.take_along_axis(t_prev, flat, axis=1).reshape(
                R, next_tiles, bp)
            vp = jnp.take_along_axis(vprev, flat, axis=1).reshape(
                R, next_tiles, bp)
            ok = (tp[:, :, None, :] >= tn[..., None] - t_hi) & (
                tp[:, :, None, :] < tn[..., None] - t_lo)      # [R, NT, BN, BP]
            contrib = jnp.max(jnp.where(ok, vp[:, :, None, :], NEG), axis=-1)
            return jnp.maximum(acc, jnp.where(live[:, :, None], contrib, NEG))

        acc = jax.lax.fori_loop(
            0, max_num, scan_tile,
            jnp.full((R, next_tiles, bn), NEG, jnp.float32))
        v = jnp.where(jnp.isfinite(t_next), acc.reshape(R, cap), NEG)
        nsup = nsup + jnp.sum(v > NEG, axis=-1).astype(jnp.int32)

    # --- in-VMEM count_scan_write compaction (paper §IV-D): prefix-scan the
    # keep flags, then invert the scatter-write into a gather — row r's k-th
    # surviving interval sits at searchsorted(csum[r], k+1). Bit-identical to
    # the scatter formulation and far cheaper on both XLA-CPU and TPU.
    ends = times_ref[:, levels, :]
    valid = (v > NEG) & jnp.isfinite(ends)
    keep = valid.astype(jnp.int32)
    csum = jnp.cumsum(keep, axis=1)
    targets = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)[0] + 1
    src = jax.vmap(lambda c: jnp.searchsorted(c, targets, side="left"))(csum)
    src_c = jnp.minimum(src, cap - 1)
    live = src < cap
    sT = jnp.where(live, jnp.take_along_axis(v, src_c, axis=1), NEG).T
    eT = jnp.where(live, jnp.take_along_axis(ends, src_c, axis=1), jnp.inf).T
    m = csum[:, -1]                                            # valid per row

    # --- greedy non-overlap scheduling: exactly scheduling.greedy_scan_state
    # (take iff valid & start > prev_end, strict — ties rejected), folded
    # over ONLY the compacted prefix: max(m) trips instead of cap.
    def step(j, carry):
        prev_e, cnt = carry
        s_j = jax.lax.dynamic_slice_in_dim(sT, j, 1, axis=0)[0]
        e_j = jax.lax.dynamic_slice_in_dim(eT, j, 1, axis=0)[0]
        take = (j < m) & (s_j > prev_e)
        return (jnp.where(take, e_j, prev_e), cnt + take.astype(jnp.int32))

    prev_e, cnt = jax.lax.fori_loop(
        0, jnp.max(m), step,
        (pend_ref[:, 0], jnp.zeros((R,), jnp.int32)))
    count_ref[:, 0] = pcnt_ref[:, 0] + cnt
    end_ref[:, 0] = prev_e
    nsup_ref[:, 0] = nsup


@functools.partial(
    jax.jit,
    static_argnames=("block_next", "block_prev", "chunk", "interpret"),
)
def count_batch_pallas(
    times_by_sym: jax.Array,    # f32[B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,           # f32[B, N-1]
    t_high: jax.Array,          # f32[B, N-1]
    start_tile: jax.Array,      # i32[B, N-1, next_tiles]
    num_tiles: jax.Array,       # i32[B, N-1, next_tiles]
    prev_end: jax.Array,        # f32[B] carried greedy prev_end
    prev_count: jax.Array,      # i32[B] carried greedy count
    *,
    block_next: int = 256,
    block_prev: int = 256,
    chunk: int = 8,
    interpret: bool = False,
) -> tuple:
    """Whole-batch tracking + compaction + greedy counting, ONE launch.

    Returns ``(counts i32[B], end_out f32[B], n_superset i32[B])``: the
    final non-overlapped counts (carry-in ``prev_count`` included), the
    carried-out greedy ``prev_end`` state, and the tracked superset sizes.
    Occurrence intervals never round-trip to HBM — only these O(B) scalars
    leave the kernel. ``chunk`` is the number of episode rows each grid step
    owns; the batch is row-padded (+inf times scan zero tiles: a no-op) up
    to a chunk multiple.
    """
    batch, n, cap = times_by_sym.shape
    levels = n - 1
    if levels < 1:
        raise ValueError("need at least a 2-symbol episode for the kernel")
    bn = min(block_next, cap)
    bp = min(block_prev, cap)
    if cap % bn or cap % bp:
        raise ValueError(f"cap={cap} must be a multiple of block sizes {bn},{bp}")
    next_tiles = cap // bn
    r = max(1, min(chunk, batch))
    nchunks = -(-batch // r)
    pad = nchunks * r - batch
    if pad:
        def padrow(x, fill):
            return jnp.concatenate(
                [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        times_by_sym = padrow(times_by_sym, jnp.inf)
        t_low = padrow(t_low, 0)
        t_high = padrow(t_high, 0)
        start_tile = padrow(start_tile, 0)
        num_tiles = padrow(num_tiles, 0)
        prev_end = padrow(prev_end, NEG)
        prev_count = padrow(prev_count, 0)
    kernel = pl.pallas_call(
        functools.partial(
            _count_batch_kernel, levels=levels, block_next=bn, block_prev=bp,
            next_tiles=next_tiles),
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((r, n, cap), lambda c: (c, 0, 0)),
            pl.BlockSpec((r, levels), lambda c: (c, 0)),
            pl.BlockSpec((r, levels), lambda c: (c, 0)),
            pl.BlockSpec((r, levels, next_tiles), lambda c: (c, 0, 0)),
            pl.BlockSpec((r, levels, next_tiles), lambda c: (c, 0, 0)),
            pl.BlockSpec((r, 1), lambda c: (c, 0)),
            pl.BlockSpec((r, 1), lambda c: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r, 1), lambda c: (c, 0)),
            pl.BlockSpec((r, 1), lambda c: (c, 0)),
            pl.BlockSpec((r, 1), lambda c: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nchunks * r, 1), jnp.int32),
            jax.ShapeDtypeStruct((nchunks * r, 1), jnp.float32),
            jax.ShapeDtypeStruct((nchunks * r, 1), jnp.int32),
        ],
        interpret=interpret,
    )
    cnt, end, nsup = kernel(
        times_by_sym, t_low.astype(jnp.float32), t_high.astype(jnp.float32),
        start_tile, num_tiles,
        prev_end.astype(jnp.float32)[:, None], prev_count[:, None])
    return cnt[:batch, 0], end[:batch, 0], nsup[:batch, 0]
