"""Pallas TPU kernel for one level of parallel local tracking.

This is the compute hot-spot the paper optimizes (Algorithm 2): for every
event ``t`` of the next episode symbol, combine (max-reduce) the
latest-start values of all previous-symbol events ``s`` inside the
inter-event window ``t - hi <= s < t - lo``.

TPU adaptation (DESIGN.md §2): instead of one divergent scanning thread per
event (the CUDA formulation), the time axis is tiled into VMEM blocks. The
grid is ``(next_tiles, window_tiles)``; for next-tile ``i`` the inner
dimension walks the ``window_tiles`` previous-symbol tiles that can overlap
its constraint window, starting at a *scalar-prefetched* tile offset
(computed with searchsorted in ops.py — the paper's per-type index made
block-level). Inside the kernel a (BN, BP) broadcast compare + row max
replaces the divergent scan; max-accumulation is idempotent so clamped /
duplicated boundary tiles are harmless.

VMEM per grid step: BN + 2*BP + BN*BP fp32 ≈ 0.27 MB at BN=BP=256 — far
under the ~16 MB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _track_level_kernel(
    # scalar-prefetch operands
    start_tile_ref,     # i32[next_tiles] first prev-tile per next-tile
    window_ref,         # f32[2] = (t_low, t_high)
    # array operands
    t_next_ref,         # f32[BN]   block of next-symbol times
    t_prev_ref,         # f32[BP]   block of prev-symbol times
    v_prev_ref,         # f32[BP]   block of prev-symbol latest-start values
    # outputs
    v_next_ref,         # f32[BN]   accumulated latest-start values
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_next_ref[...] = jnp.full_like(v_next_ref, NEG)

    t_lo = window_ref[0]
    t_hi = window_ref[1]
    t_next = t_next_ref[...]                       # [BN]
    t_prev = t_prev_ref[...]                       # [BP]
    v_prev = v_prev_ref[...]                       # [BP]

    # window: t - hi <= s < t - lo   (paper: lo < t - s <= hi)
    ok = (t_prev[None, :] >= t_next[:, None] - t_hi) & (
        t_prev[None, :] < t_next[:, None] - t_lo)          # [BN, BP]
    contrib = jnp.max(jnp.where(ok, v_prev[None, :], NEG), axis=1)
    v_next_ref[...] = jnp.maximum(v_next_ref[...], contrib)


@functools.partial(
    jax.jit,
    static_argnames=("block_next", "block_prev", "window_tiles", "interpret"),
)
def track_level_pallas(
    t_prev: jax.Array,      # f32[cap] sorted, +inf padded
    v_prev: jax.Array,      # f32[cap] latest-start values (-inf pad)
    t_next: jax.Array,      # f32[cap] sorted, +inf padded
    t_low,
    t_high,
    *,
    block_next: int = 256,
    block_prev: int = 256,
    window_tiles: int = 0,   # 0 => cover all prev tiles (always exact)
    interpret: bool = False,
) -> jax.Array:
    """One tracking level. Exact iff the constraint window of every next
    block fits in ``window_tiles`` prev blocks (0 = all blocks, always
    exact; ops.py computes a tight bound)."""
    cap = t_prev.shape[0]
    if t_next.shape[0] != cap or v_prev.shape[0] != cap:
        raise ValueError("equal-capacity level arrays required")
    bn = min(block_next, cap)
    bp = min(block_prev, cap)
    if cap % bn or cap % bp:
        raise ValueError(f"cap={cap} must be a multiple of block sizes {bn},{bp}")
    next_tiles = cap // bn
    prev_tiles = cap // bp
    wt = prev_tiles if window_tiles == 0 else min(window_tiles, prev_tiles)

    # first prev tile whose block may intersect the earliest window of the
    # next tile:   first s >= min_t(t_next tile) - t_high
    tile_min = t_next.reshape(next_tiles, bn)[:, 0]
    start_idx = jnp.searchsorted(t_prev, tile_min - jnp.float32(t_high), side="left")
    start_tile = jnp.clip(
        (start_idx // bp).astype(jnp.int32), 0, jnp.int32(max(prev_tiles - wt, 0)))
    window = jnp.asarray([t_low, t_high], jnp.float32)

    grid = (next_tiles, wt)
    kernel = pl.pallas_call(
        _track_level_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn,), lambda i, j, st, w: (i,)),
                pl.BlockSpec((bp,), lambda i, j, st, w: (st[i] + j,)),
                pl.BlockSpec((bp,), lambda i, j, st, w: (st[i] + j,)),
            ],
            out_specs=pl.BlockSpec((bn,), lambda i, j, st, w: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.float32),
        interpret=interpret,
    )
    return kernel(start_tile, window, t_next, t_prev, v_prev)
