"""Version-compatibility shims for jax APIs that moved between releases.

Keeps the rest of the codebase on one spelling regardless of the installed
jax: ``shard_map`` graduated from ``jax.experimental.shard_map`` to
``jax.shard_map``, and mesh ``axis_types`` only exist on newer versions
(see launch/mesh.py for the latter).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # older jax (< 0.6)
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the replication checker disabled.

    Needed when the body contains a ``pallas_call``: the checker has no
    replication rule for it ("No replication rule for pallas_call"). The
    disabling kwarg moved across jax releases (``check_rep`` ->
    ``check_vma``), and the newest versions may drop it entirely once the
    rule exists — pick by signature so a genuine TypeError from shard_map
    itself (bad specs, bad mesh) propagates instead of being swallowed.
    """
    import inspect

    try:
        params = inspect.signature(shard_map).parameters
    except (TypeError, ValueError):      # C-accelerated / wrapped callable
        params = None
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if params is not None:
        for name in ("check_rep", "check_vma"):
            if name in params:
                kwargs[name] = False
                break
        return shard_map(f, **kwargs)
    # signature unavailable: probe, but re-raise the bare call's real error
    for name in ("check_rep", "check_vma"):
        try:
            return shard_map(f, **kwargs, **{name: False})
        except TypeError:
            continue
    return shard_map(f, **kwargs)


def pcast_varying(x, axes):
    """Mark ``x`` as varying over manual ``axes`` inside shard_map.

    Newer jax enforces varying-manual-axes typing on scan carries and
    provides ``lax.pcast`` to coerce; older versions have neither the check
    nor the primitive, so this is an identity there.
    """
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axes, to="varying")


def axis_size(axis_name):
    """Size of a mapped mesh axis; ``lax.axis_size`` on newer jax, the
    psum-of-ones identity on older versions."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


__all__ = ["shard_map", "shard_map_unchecked", "pcast_varying", "axis_size"]
