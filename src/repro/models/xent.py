"""Sequence-chunked softmax cross-entropy.

The lm_head matmul + softmax over a 100k-256k vocabulary is the largest
single activation of the whole train step ([b, s, V] fp32 — ~10 GB/device
for qwen3 at 4k/batch-64 — plus its gradient). Chunking the sequence axis
with a rematerialized chunk body keeps the live footprint at
[b, chunk, V_shard] in both directions; the lm_head weight is re-read per
chunk (cheap: it stays vocab-sharded over the model axis).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _pick_chunk(s: int, want: int) -> int:
    c = min(want, s)
    while s % c:
        c -= 1
    return c


def chunked_xent(
    x: jax.Array,          # [b, s, d] final hidden states
    w: jax.Array,          # [d, V] head weight (pass embed.T for tied)
    targets: jax.Array,    # [b, s] int32
    mask: jax.Array,       # [b, s] f32
    *,
    chunk: int = 512,
    constrain=lambda t, name: t,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (sum of masked token NLL, sum of mask)."""
    b, s, d = x.shape
    c = _pick_chunk(s, chunk)
    nc = s // c
    xs = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

    def chunk_body(xc, tc, mc):
        logits = (xc.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)).astype(
            jnp.float32)
        logits = constrain(logits, "logits")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - lt) * mc
        return jnp.sum(nll), jnp.sum(mc)

    chunk_body = jax.checkpoint(chunk_body)

    def scan_body(carry, xs_):
        ce, n = carry
        cs, cn = chunk_body(*xs_)
        return (ce + cs, n + cn), None

    (ce, n), _ = lax.scan(scan_body, (jnp.float32(0.0), jnp.float32(0.0)),
                          (xs, ts, ms))
    return ce, n
