"""Shared neural-net layers (pure-function style: params are dict pytrees).

Conventions:
  * activations default bf16, params fp32 (cast at use), reductions fp32;
  * every init function takes an explicit PRNG key and returns a dict;
  * logical sharding axes for each weight are declared alongside init in
    *_specs() twins, consumed by repro.distributed.sharding.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

Init = jax.nn.initializers


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_specs(axes_in: str, axes_out: str, *, bias: bool = False):
    p = {"w": (axes_in, axes_out)}
    if bias:
        p["b"] = (axes_out,)
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm_specs():
    return {"g": (None,)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["g"].astype(jnp.float32)
    return y.astype(x.dtype)


def head_rmsnorm(g, x, eps: float = 1e-5):
    """qk-norm: RMS over the head_dim of [*, heads, head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


# ------------------------------- RoPE --------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------- MLP ---------------------------------------


def mlp_init(key, d: int, ff: int, kind: str = "swiglu", bias: bool = False):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], d, ff, bias=bias),
            "up": dense_init(ks[1], d, ff, bias=bias),
            "down": dense_init(ks[2], ff, d, bias=bias),
        }
    return {
        "up": dense_init(ks[1], d, ff, bias=bias),
        "down": dense_init(ks[2], ff, d, bias=bias),
    }


def mlp_specs(kind: str = "swiglu", bias: bool = False):
    if kind == "swiglu":
        return {
            "gate": dense_specs("embed", "ff", bias=bias),
            "up": dense_specs("embed", "ff", bias=bias),
            "down": dense_specs("ff", "embed", bias=bias),
        }
    return {
        "up": dense_specs("embed", "ff", bias=bias),
        "down": dense_specs("ff", "embed", bias=bias),
    }


def mlp(p, x, kind: str = "swiglu"):
    if kind == "swiglu":
        return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# ----------------------------- Embeddings -----------------------------------


def embed_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed_specs():
    return {"table": ("vocab", "embed")}


def embed_lookup(p, tokens, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p, x, compute_dtype=jnp.bfloat16):
    return x.astype(compute_dtype) @ p["table"].astype(compute_dtype).T
