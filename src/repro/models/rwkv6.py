"""RWKV-6 "Finch" block: time-mix with data-dependent per-channel decay +
channel-mix (arXiv:2404.05892), in chunked linear-attention form.

The WKV recurrence per head (state S in R^{K x V}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Train path is *chunkwise parallel* (the same transformation family the
paper applies to episode counting: sequential recurrence -> scan + blocked
parallel work): within a chunk of L tokens the pairwise term is an
attention-like einsum with per-channel decay factors
exp(b_{t-1} - b_s) <= 1 (b = cumulative log-decay, monotone decreasing, so
all intra-chunk exponents are safe); across chunks a ``lax.scan`` carries
S. Per-step log-decay is clamped to [-DECAY_CLAMP, 0] so the chunk-boundary
normalizer exp(b_{t-1} - b_{L-1}) stays within fp32 range (DESIGN.md notes
this bounded-decay deviation from the unbounded official parameterization).

Decode is the exact single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import layers

DECAY_CLAMP = 1.5   # max |log w| per step; exp bound within a chunk = L*1.5
CHUNK = 32


def init(key, cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_rwkv_heads
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    return {
        # token-shift interpolation weights (static lerp per channel)
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "w_r": layers.dense_init(ks[0], d, d),
        "w_k": layers.dense_init(ks[1], d, d),
        "w_v": layers.dense_init(ks[2], d, d),
        "w_g": layers.dense_init(ks[3], d, d),
        # data-dependent decay lora: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "dec_a": jax.random.normal(ks[4], (d, cfg.decay_lora), jnp.float32) * 0.02,
        "dec_b": jax.random.normal(ks[5], (cfg.decay_lora, d), jnp.float32) * 0.02,
        "u": jax.random.normal(ks[6], (h, hd), jnp.float32) * 0.1,  # bonus
        "ln_x": jnp.ones((d,), jnp.float32),  # per-head group norm gain
        "w_o": layers.dense_init(ks[7], d, d),
        # channel-mix
        "mu_ck": jnp.full((d,), 0.5, jnp.float32),
        "mu_cr": jnp.full((d,), 0.5, jnp.float32),
        "c_k": layers.dense_init(ks[8], d, cfg.d_ff),
        "c_v": layers.dense_init(ks[9], cfg.d_ff, d),
        "c_r": layers.dense_init(ks[10], d, d),
    }


def specs(cfg: ArchConfig):
    return {
        "mu_r": (None,), "mu_k": (None,), "mu_v": (None,), "mu_w": (None,),
        "mu_g": (None,),
        "w_r": layers.dense_specs("embed", "q_proj"),
        "w_k": layers.dense_specs("embed", "q_proj"),
        "w_v": layers.dense_specs("embed", "q_proj"),
        "w_g": layers.dense_specs("embed", "q_proj"),
        "w0": (None,), "dec_a": ("embed", None), "dec_b": (None, "q_proj"),
        "u": ("heads", None),
        "ln_x": (None,),
        "w_o": layers.dense_specs("q_proj", "embed"),
        "mu_ck": (None,), "mu_cr": (None,),
        "c_k": layers.dense_specs("embed", "ff"),
        "c_v": layers.dense_specs("ff", "embed"),
        "c_r": layers.dense_specs("embed", "q_proj"),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` at t=0). x: [b, s, d]."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _log_decay(p, xw):
    """Per-channel log-decay in [-DECAY_CLAMP, 0). xw: [b, s, d] f32."""
    lora = jnp.tanh(xw @ p["dec_a"].astype(xw.dtype)) @ p["dec_b"].astype(xw.dtype)
    return -jnp.clip(jnp.exp(p["w0"].astype(xw.dtype) + lora), 1e-4, DECAY_CLAMP)


def _heads(x, h, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, h, hd)


def time_mix(p, cfg: ArchConfig, x, state=None):
    """WKV time-mix over a full sequence (chunked). x: [b, s, d].

    Returns (out, final_state [b, h, hd, hd])."""
    b, s, d = x.shape
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xx = _shift(x)
    r = layers.dense(p["w_r"], _lerp(x, xx, p["mu_r"]))
    k = layers.dense(p["w_k"], _lerp(x, xx, p["mu_k"]))
    v = layers.dense(p["w_v"], _lerp(x, xx, p["mu_v"]))
    g = jax.nn.silu(layers.dense(p["w_g"], _lerp(x, xx, p["mu_g"])))
    xw = _lerp(x, xx, p["mu_w"]).astype(jnp.float32)
    logw = _log_decay(p, xw)                                   # [b, s, d]

    r4 = _heads(r, h, hd).astype(jnp.float32)
    k4 = _heads(k, h, hd).astype(jnp.float32)
    v4 = _heads(v, h, hd).astype(jnp.float32)
    lw4 = _heads(logw, h, hd)
    u = p["u"].astype(jnp.float32)                             # [h, hd]

    L = min(cfg.rwkv_chunk, s)
    while s % L:
        L -= 1
    nc = s // L
    rc = r4.reshape(b, nc, L, h, hd)
    kc = k4.reshape(b, nc, L, h, hd)
    vc = v4.reshape(b, nc, L, h, hd)
    wc = lw4.reshape(b, nc, L, h, hd)

    bcum = jnp.cumsum(wc, axis=2)                              # inclusive [b,nc,L,h,hd]
    bex = bcum - wc                                            # exclusive (b_{t-1})
    btot = bcum[:, :, -1]                                      # [b, nc, h, hd]

    # intra-chunk pairwise term: scores[t,s] = sum_i r_t,i k_s,i e^{bex_t - bcum_s}
    # factor with chunk-end normalizer m = btot (most negative):
    #   q' = r * e^{bex - btot}  (exponent >= 0, bounded by L*DECAY_CLAMP)
    #   k' = k * e^{btot - bcum} ... wait we need e^{-(bcum_s - btot)} <= 1
    qp = rc * jnp.exp(bex - btot[:, :, None])
    kp = kc * jnp.exp(btot[:, :, None] - bcum)
    scores = jnp.einsum("bclhi,bcmhi->bchlm", qp, kp)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)              # strict s < t
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    o_intra = jnp.einsum("bchlm,bcmhv->bclhv", scores, vc)
    # current-token bonus: o_t += (sum_i r_i u_i k_i) v_t
    o_bonus = jnp.sum(rc * u[None, None, None] * kc, axis=-1, keepdims=True) * vc

    # inter-chunk: scan carrying state S [b, h, hd(K), hd(V)]
    # contribution of carry-in: o_t += (r_t * e^{bex_t}) @ S_in
    # state update: S_out = e^{btot} * S_in + sum_s (k_s e^{btot - bcum_s}) v_s^T
    kpv = jnp.einsum("bclhi,bclhv->bchiv", kp, vc)             # decayed kv outer
    q_carry = rc * jnp.exp(bex)                                # exponent <= 0

    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    def chunk_step(S, inputs):
        qcar, kv, dec = inputs                                 # [b,L,h,i], [b,h,i,v], [b,h,i]
        o_car = jnp.einsum("blhi,bhiv->blhv", qcar, S)
        S_new = dec[..., None] * S + kv
        return S_new, o_car

    xs = (
        jnp.moveaxis(q_carry, 1, 0),       # [nc, b, L, h, i]
        jnp.moveaxis(kpv, 1, 0),           # [nc, b, h, i, v]
        jnp.moveaxis(jnp.exp(btot), 1, 0)  # [nc, b, h, i]
    )
    state, o_carry = lax.scan(chunk_step, state, xs)
    o_carry = jnp.moveaxis(o_carry, 0, 1)                      # [b, nc, L, h, v]

    o = (o_intra + o_bonus + o_carry).reshape(b, s, h * hd)
    # per-head group norm then gate
    o = _groupnorm(o, p["ln_x"], h)
    out = layers.dense(p["w_o"], (o * g.astype(o.dtype)))
    return out, state


def _groupnorm(x, gain, h, eps=64e-5):
    b, s, d = x.shape
    xg = x.reshape(b, s, h, d // h).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xn = (xg - mu) * lax.rsqrt(var + eps)
    return (xn.reshape(b, s, d) * gain.astype(jnp.float32)).astype(jnp.bfloat16)


def channel_mix(p, cfg: ArchConfig, x, last=None):
    xx = _shift(x, last)
    k = layers.dense(p["c_k"], _lerp(x, xx, p["mu_ck"]))
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(layers.dense(p["c_r"], _lerp(x, xx, p["mu_cr"])))
    return r * layers.dense(p["c_v"], k)


def forward(p, cfg: ArchConfig, x, positions=None):
    """Full RWKV block: time-mix + channel-mix with pre-norms handled by
    the caller (blocks.py applies norms/residuals)."""
    del positions
    out, _ = time_mix(p, cfg, x)
    return out


# ------------------------------ decode path ---------------------------------


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    d = cfg.d_model
    return {
        "S": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, d), jnp.bfloat16),   # last input (time-mix)
        "x_cm": jnp.zeros((batch, d), jnp.bfloat16),   # last input (channel-mix)
    }


def cache_specs(cfg: ArchConfig):
    return {"S": ("batch", "heads", None, None),
            "x_tm": ("batch", None), "x_cm": ("batch", None)}


def decode_time_mix(p, cfg: ArchConfig, cache, x):
    """Exact single-step recurrence. x: [b, 1, d]."""
    b, _, d = x.shape
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xx = cache["x_tm"][:, None].astype(x.dtype)
    r = layers.dense(p["w_r"], _lerp(x, xx, p["mu_r"]))[:, 0]
    k = layers.dense(p["w_k"], _lerp(x, xx, p["mu_k"]))[:, 0]
    v = layers.dense(p["w_v"], _lerp(x, xx, p["mu_v"]))[:, 0]
    g = jax.nn.silu(layers.dense(p["w_g"], _lerp(x, xx, p["mu_g"])))[:, 0]
    xw = _lerp(x, xx, p["mu_w"]).astype(jnp.float32)[:, 0]
    logw = _log_decay(p, xw[:, None])[:, 0]                    # [b, d]

    rh = r.reshape(b, h, hd).astype(jnp.float32)
    kh = k.reshape(b, h, hd).astype(jnp.float32)
    vh = v.reshape(b, h, hd).astype(jnp.float32)
    wh = jnp.exp(logw.reshape(b, h, hd))
    u = p["u"].astype(jnp.float32)

    S = cache["S"]
    cur = S + (u[None] * kh)[..., None] * vh[:, :, None, :]     # [b,h,i,v]
    o = jnp.einsum("bhi,bhiv->bhv", rh, cur).reshape(b, 1, h * hd)
    S_new = wh[..., None] * S + kh[..., None] * vh[:, :, None, :]
    o = _groupnorm(o, p["ln_x"], h)
    out = layers.dense(p["w_o"], o * g[:, None].astype(o.dtype))
    new_cache = dict(cache, S=S_new, x_tm=x[:, 0].astype(jnp.bfloat16))
    return out, new_cache


def decode_channel_mix(p, cfg: ArchConfig, cache, x):
    out = channel_mix(p, cfg, x, last=cache["x_cm"].astype(x.dtype))
    return out, dict(cache, x_cm=x[:, 0].astype(jnp.bfloat16))
