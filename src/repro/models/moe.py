"""Mixture-of-Experts FFN: top-k routing, shared + fine-grained experts.

Dispatch is the capacity-based scatter/gather formulation (no giant GShard
one-hot einsum tensors, no global sort): position-in-expert comes from a
cumulative sum over the token axis, tokens are scattered into a static
[E, C, d] buffer (k scatters of [T, d]) and gathered back after the batched
expert GEMMs. Expert weights and the [E, C, *] buffers shard their leading
E axis over the mesh ``model`` axis (expert parallelism); GSPMD inserts the
dispatch all-to-alls. Over-capacity tokens are dropped (standard Switch
semantics) — ``capacity_factor`` controls the drop rate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoECfg
from . import layers
from ..compat import shard_map


def _capacity(n_tokens: int, m: MoECfg) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(128, -(-c // 128) * 128)  # multiple of 128 for clean layouts


def init(key, cfg: ArchConfig):
    m = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    e = m.n_experts
    s = d ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * s},
        "w_gate": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * s,
        "w_up": jax.random.normal(ks[2], (e, d, ff), jnp.float32) * s,
        "w_down": jax.random.normal(ks[3], (e, ff, d), jnp.float32) * (ff ** -0.5),
    }
    if m.n_shared:
        p["shared"] = [
            layers.mlp_init(k, d, ff, "swiglu", cfg.use_bias)
            for k in jax.random.split(ks[4], m.n_shared)
        ]
    return p


def specs(cfg: ArchConfig):
    m = cfg.moe
    p = {
        "router": {"w": ("embed", None)},
        "w_gate": ("experts", "embed", "ff"),
        "w_up": ("experts", "embed", "ff"),
        "w_down": ("experts", "ff", "embed"),
    }
    if m.n_shared:
        p["shared"] = [layers.mlp_specs("swiglu", cfg.use_bias)
                       for _ in range(m.n_shared)]
    return p


def forward(p, cfg: ArchConfig, x, constrain=lambda t, name: t, mesh=None):
    """x: [b, s, d] -> [b, s, d].

    With a mesh, dispatch runs under shard_map (forward_sharded): tokens
    stay on their data shard, each ``model`` shard routes into buffers for
    its *local* experts only, and a single psum over ``model`` combines —
    the collective cost of a Megatron FFN, with no GSPMD resharding of the
    token axis. Without a mesh (single-device smoke tests) the global
    scatter formulation below runs as-is.
    """
    if mesh is not None and "model" in mesh.axis_names:
        return forward_sharded(p, cfg, x, mesh)
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    cap = _capacity(t, m)
    xt = x.reshape(t, d)

    # --- routing ---
    logits = layers.dense(p["router"], xt, compute_dtype=jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)                       # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- position-in-expert via cumulative counts (capacity enforcement) ---
    # top_k indices are distinct per token, so tok_e[t, e] is 0/1 and the
    # within-token offset is always zero: position = # earlier (t', e) hits.
    tok_e = jnp.zeros((t, m.n_experts), jnp.int32).at[
        jnp.arange(t, dtype=jnp.int32)[:, None], top_i].add(1)          # [T, E]
    cum = jnp.cumsum(tok_e, axis=0) - tok_e                             # excl. [T, E]
    pos_tj = jnp.take_along_axis(cum, top_i, axis=1)                    # [T, k]
    keep = pos_tj < cap

    # --- dispatch: k scatters of [T, d] into [E, C, d] ---
    buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
    for j in range(m.top_k):
        e_j = top_i[:, j]
        c_j = jnp.where(keep[:, j], pos_tj[:, j], cap)  # park dropped at C
        buf = buf.at[e_j, c_j].set(xt, mode="drop")
    buf = constrain(buf, "moe_buffer")

    # --- expert GEMMs (batched over E) ---
    cd = jnp.bfloat16
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf.astype(cd),
                               p["w_gate"].astype(cd)))
    h = h * jnp.einsum("ecd,edf->ecf", buf.astype(cd), p["w_up"].astype(cd))
    h = constrain(h, "moe_hidden")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))
    out_buf = constrain(out_buf, "moe_buffer")

    # --- combine: gather back and weight by router prob ---
    yt = jnp.zeros((t, d), x.dtype)
    for j in range(m.top_k):
        e_j = top_i[:, j]
        c_j = jnp.where(keep[:, j], pos_tj[:, j], 0)
        gj = out_buf[e_j, c_j]                                          # [T, d]
        w_j = (top_p[:, j] * keep[:, j]).astype(gj.dtype)
        yt = yt + w_j[:, None] * gj

    # --- shared experts (always-on fine-grained residual experts) ---
    if m.n_shared:
        for sp in p["shared"]:
            yt = yt + layers.mlp(sp, xt, "swiglu")

    return yt.reshape(b, s, d), _aux_metrics(tok_e, keep, cap)


def _expert_ffn(buf, p_gate, p_up, p_down):
    cd = jnp.bfloat16
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf.astype(cd), p_gate.astype(cd)))
    h = h * jnp.einsum("ecd,edf->ecf", buf.astype(cd), p_up.astype(cd))
    return jnp.einsum("ecf,efd->ecd", h, p_down.astype(cd))


def forward_sharded(p, cfg: ArchConfig, x, mesh):
    """Expert-parallel MoE under shard_map (see forward() docstring)."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in batch_axes:
        n_data *= mesh.shape[a]
    if b % n_data or m.n_experts % mesh.shape["model"]:
        # fall back to the global formulation when shapes do not divide
        return forward(p, cfg, x, mesh=None)
    e_local = m.n_experts // mesh.shape["model"]
    t_local = (b // n_data) * s
    cap = _capacity(t_local, m)

    def local_fn(x_blk, router_w, w_gate, w_up, w_down):
        # x_blk: [b_l, s, d]; w_*: [E_local, ...]
        b_l = x_blk.shape[0]
        xt = x_blk.reshape(b_l * s, d)
        logits = (xt.astype(jnp.float32)
                  @ router_w.astype(jnp.float32))               # [T_l, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        e_off = jax.lax.axis_index("model") * e_local
        tl = xt.shape[0]
        # per-local-expert positions via cumulative counts
        tok_e = jnp.zeros((tl, e_local), jnp.int32)
        loc_i = top_i - e_off                                   # [T_l, k]
        local = (loc_i >= 0) & (loc_i < e_local)
        rows = jnp.broadcast_to(jnp.arange(tl, dtype=jnp.int32)[:, None],
                                loc_i.shape)
        tok_e = tok_e.at[rows, jnp.where(local, loc_i, 0)].add(
            local.astype(jnp.int32))
        cum = jnp.cumsum(tok_e, axis=0) - tok_e
        buf = jnp.zeros((e_local, cap, d), x_blk.dtype)
        pos_cache = []
        for j in range(m.top_k):
            lj = loc_i[:, j]
            pj = jnp.take_along_axis(cum, jnp.clip(loc_i[:, j:j+1], 0, e_local - 1),
                                     axis=1)[:, 0]
            ok = local[:, j] & (pj < cap)
            buf = buf.at[jnp.where(ok, lj, e_local),
                         jnp.where(ok, pj, 0)].set(xt, mode="drop")
            pos_cache.append((lj, pj, ok))

        out_buf = _expert_ffn(buf, w_gate, w_up, w_down)

        yt = jnp.zeros((tl, d), jnp.float32)
        for j in range(m.top_k):
            lj, pj, ok = pos_cache[j]
            gj = out_buf[jnp.where(ok, lj, 0), jnp.where(ok, pj, 0)]
            w_j = top_p[:, j] * ok
            yt = yt + w_j[:, None] * gj.astype(jnp.float32)
        yt = jax.lax.psum(yt.astype(jnp.bfloat16), "model")
        return yt.astype(x_blk.dtype).reshape(b_l, s, d)

    x_spec = P(batch_axes if len(batch_axes) > 1 else (batch_axes or (None,))[0],
               None, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(), P("model"), P("model"), P("model")),
        out_specs=x_spec,
    )
    yt = fn(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared:
        xt = x.reshape(b * s, d)
        add = jnp.zeros_like(xt)
        for sp in p["shared"]:
            add = add + layers.mlp(sp, xt, "swiglu")
        yt = yt + add.reshape(b, s, d).astype(yt.dtype)
    return yt, {}


def _aux_metrics(tok_e, keep, cap):
    load = jnp.sum(tok_e, axis=0)
    return {
        "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
        "moe_max_load": jnp.max(load) / jnp.maximum(1, cap),
    }


def load_balance_loss(p, cfg: ArchConfig, x):
    """Switch-style auxiliary load-balance loss (fraction * probability)."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = layers.dense(p["router"], xt, compute_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_i = jax.lax.top_k(probs, m.top_k)[1]
    hits = jnp.zeros((m.n_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac = hits / (xt.shape[0] * m.top_k)
    imp = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac * imp)
