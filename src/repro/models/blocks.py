"""Decoder blocks + the scan-over-layers stack.

Layers are grouped into *super-blocks* of ``len(cfg.block_pattern)`` layers
(e.g. recurrentgemma's (rec, rec, attn)); identical super-blocks are stacked
along a leading axis and iterated with ``lax.scan`` so HLO size — and hence
512-device compile time — is O(1) in depth. Layers that do not fill a whole
super-block are unrolled as ``tail`` layers.

Block kinds:
  attn   pre-norm GQA attention + pre-norm FFN (dense or MoE)
  local  same, with a sliding window
  rec    pre-norm RG-LRU recurrent block + pre-norm FFN
  rwkv   RWKV6 time-mix + channel-mix
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import attention, layers, moe, rglru, rwkv6

Constrain = Callable[[jax.Array, str], jax.Array]
_IDENT: Constrain = lambda x, name: x


def _ffn_init(key, cfg: ArchConfig):
    if cfg.moe is not None:
        return moe.init(key, cfg)
    return layers.mlp_init(key, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.use_bias)


def _ffn_specs(cfg: ArchConfig):
    if cfg.moe is not None:
        return moe.specs(cfg)
    return layers.mlp_specs(cfg.mlp, cfg.use_bias)


def _ffn_apply(p, cfg: ArchConfig, x, constrain=_IDENT, mesh=None):
    if cfg.moe is not None:
        y, _ = moe.forward(p, cfg, x, constrain, mesh=mesh)
        aux = moe.load_balance_loss(p, cfg, x)
        return y, aux
    return layers.mlp(p, x, cfg.mlp), jnp.float32(0.0)


def block_init(key, cfg: ArchConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "rwkv":
        return {
            "norm1": layers.rmsnorm_init(cfg.d_model),
            "norm2": layers.rmsnorm_init(cfg.d_model),
            "rwkv": rwkv6.init(k1, cfg),
        }
    mix = rglru.init(k1, cfg) if kind == "rec" else attention.init(k1, cfg)
    return {
        "norm1": layers.rmsnorm_init(cfg.d_model),
        "mix": mix,
        "norm2": layers.rmsnorm_init(cfg.d_model),
        "ffn": _ffn_init(k2, cfg),
    }


def block_specs(cfg: ArchConfig, kind: str):
    if kind == "rwkv":
        return {
            "norm1": layers.rmsnorm_specs(),
            "norm2": layers.rmsnorm_specs(),
            "rwkv": rwkv6.specs(cfg),
        }
    mix = rglru.specs(cfg) if kind == "rec" else attention.specs(cfg)
    return {
        "norm1": layers.rmsnorm_specs(),
        "mix": mix,
        "norm2": layers.rmsnorm_specs(),
        "ffn": _ffn_specs(cfg),
    }


def block_apply(p, cfg: ArchConfig, kind: str, x, positions, constrain=_IDENT,
                mesh=None):
    """One decoder layer (full-sequence path). Returns (x, aux_loss)."""
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "rwkv":
        x = x + rwkv6.forward(p["rwkv"], cfg, h)
        x = constrain(x, "hidden")
        h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + rwkv6.channel_mix(p["rwkv"], cfg, h2)
        return constrain(x, "hidden"), jnp.float32(0.0)
    if kind == "rec":
        x = x + rglru.forward(p["mix"], cfg, h)
    else:
        window = cfg.window if kind == "local" else None
        x = x + attention.forward(p["mix"], cfg, h, positions, window=window,
                                  constrain=constrain)
    x = constrain(x, "hidden")
    h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
    y, aux = _ffn_apply(p["ffn"], cfg, h2, constrain, mesh)
    return constrain(x + y, "hidden"), aux


# --------------------------- stacked layer stack -----------------------------


def stack_init(key, cfg: ArchConfig):
    pattern = cfg.block_pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pattern))
    if n_super < 1:
        raise ValueError(
            f"n_layers={cfg.n_layers} must cover one block_pattern {pattern}")
    keys = jax.random.split(key, len(pattern) + n_tail)
    scan_params = []
    for pos, kind in enumerate(pattern):
        sub = jax.random.split(keys[pos], n_super)
        scan_params.append(jax.vmap(lambda k: block_init(k, cfg, kind))(sub))
    tail = [
        block_init(keys[len(pattern) + i], cfg, pattern[i % len(pattern)])
        for i in range(n_tail)
    ]
    return {"scan": scan_params, "tail": tail}


def stack_specs(cfg: ArchConfig):
    pattern = cfg.block_pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pattern))
    del n_super

    def add_layer_axis(tree):
        return jax.tree.map(lambda spec: ("layers",) + tuple(spec), tree,
                            is_leaf=lambda v: isinstance(v, tuple))

    scan_specs = [add_layer_axis(block_specs(cfg, kind)) for kind in pattern]
    tail = [block_specs(cfg, pattern[i % len(pattern)]) for i in range(n_tail)]
    return {"scan": scan_specs, "tail": tail}


def stack_apply(params, cfg: ArchConfig, x, positions, *,
                constrain: Constrain = _IDENT, remat: str = "full", mesh=None):
    """Apply all layers. Returns (x, aux_loss_sum)."""
    pattern = cfg.block_pattern

    def superblock(h, slice_params):
        aux = jnp.float32(0.0)
        for pos, kind in enumerate(pattern):
            h, a = block_apply(slice_params[pos], cfg, kind, h, positions,
                               constrain, mesh)
            aux = aux + a
        return h, aux

    if remat == "full":
        superblock = jax.checkpoint(superblock)
    elif remat == "dots":
        superblock = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.checkpoint_dots)
    elif remat == "dots_no_batch":
        superblock = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_body(carry, slice_params):
        h, aux = carry
        h, a = superblock(h, slice_params)
        return (h, aux + a), None

    (x, aux), _ = lax.scan(scan_body, (x, jnp.float32(0.0)), params["scan"])
    for i, p in enumerate(params["tail"]):
        kind = pattern[i % len(pattern)]
        x, a = block_apply(p, cfg, kind, x, positions, constrain, mesh)
        aux = aux + a
    return x, aux


# ------------------------------ decode stack --------------------------------


def stack_cache_init(cfg: ArchConfig, batch: int, cache_len: int):
    pattern = cfg.block_pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pattern))

    def one(kind):
        if kind == "rwkv":
            return rwkv6.init_cache(cfg, batch)
        if kind == "rec":
            return rglru.init_cache(cfg, batch)
        window = cfg.window if kind == "local" else None
        return attention.init_cache(cfg, batch, cache_len, window=window)

    scan_caches = [
        jax.tree.map(lambda a: jnp.broadcast_to(a, (max(n_super, 1),) + a.shape),
                     one(kind))
        for kind in pattern
    ]
    tail = [one(pattern[i % len(pattern)]) for i in range(n_tail)]
    return {"scan": scan_caches, "tail": tail}


def stack_cache_specs(cfg: ArchConfig):
    pattern = cfg.block_pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pattern))
    del n_super

    def one(kind):
        if kind == "rwkv":
            return rwkv6.cache_specs(cfg)
        if kind == "rec":
            return rglru.cache_specs(cfg)
        return attention.cache_specs(cfg)

    def add_layer_axis(tree):
        return jax.tree.map(lambda spec: ("layers",) + tuple(spec), tree,
                            is_leaf=lambda v: isinstance(v, tuple))

    return {"scan": [add_layer_axis(one(k)) for k in pattern],
            "tail": [one(pattern[i % len(pattern)]) for i in range(n_tail)]}


def block_decode(p, cfg: ArchConfig, kind: str, cache, x, pos, mesh=None):
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "rwkv":
        o, cache = rwkv6.decode_time_mix(p["rwkv"], cfg, cache, h)
        x = x + o
        h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        o2, cache = rwkv6.decode_channel_mix(p["rwkv"], cfg, cache, h2)
        return x + o2, cache
    if kind == "rec":
        o, cache = rglru.decode_step(p["mix"], cfg, cache, h)
        x = x + o
    else:
        window = cfg.window if kind == "local" else None
        o, cache = attention.decode_step(p["mix"], cfg, cache, h, pos, window=window)
        x = x + o
    h2 = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
    y, _ = _ffn_apply(p["ffn"], cfg, h2, mesh=mesh)
    return x + y, cache


def stack_decode(params, cfg: ArchConfig, caches, x, pos, mesh=None):
    """One-token decode through all layers. Returns (x, new_caches)."""
    pattern = cfg.block_pattern

    def superblock(h, slice_params, slice_caches):
        new_caches = []
        for p_, kind in enumerate(pattern):
            h, c = block_decode(slice_params[p_], cfg, kind, slice_caches[p_], h,
                                pos, mesh)
            new_caches.append(c)
        return h, new_caches

    def scan_body(h, xs):
        slice_params, slice_caches = xs
        h, new_caches = superblock(h, slice_params, slice_caches)
        return h, new_caches

    x, new_scan = lax.scan(scan_body, x, (params["scan"], caches["scan"]))
    new_tail = []
    for i, p in enumerate(params["tail"]):
        kind = pattern[i % len(pattern)]
        x, c = block_decode(p, cfg, kind, caches["tail"][i], x, pos, mesh)
        new_tail.append(c)
    return x, {"scan": new_scan, "tail": new_tail}
