"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU + gating.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = exp(c * r_t * log(a_hat)),  log(a_hat) = -softplus(lambda)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train path uses ``lax.associative_scan`` over the affine maps
(h -> a*h + b), the TPU-idiomatic analogue of the paper's prefix-scan
compaction (both are Blelloch scans); decode carries (h, conv tail).
Block layout: dual-branch (gate GELU branch x RNN branch) -> out proj.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import layers

C_SCALE = 8.0


def init(key, cfg: ArchConfig):
    d, r = cfg.d_model, cfg.rnn_dim
    ks = jax.random.split(key, 7)
    return {
        "in_gate": layers.dense_init(ks[0], d, r),   # GELU gate branch
        "in_rnn": layers.dense_init(ks[1], d, r),    # recurrent branch
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, r), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((r,), jnp.float32),
        "w_a": layers.dense_init(ks[3], r, r),
        "w_x": layers.dense_init(ks[4], r, r),
        # lambda init so that a^c ~ uniform(0.9, 0.999) at r=0.5 (paper)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, r)) / (0.5 * C_SCALE))
        ).astype(jnp.float32),
        "out": layers.dense_init(ks[5], r, d),
    }


def specs(cfg: ArchConfig):
    return {
        "in_gate": layers.dense_specs("embed", "ff"),
        "in_rnn": layers.dense_specs("embed", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "w_a": layers.dense_specs("ff", "ff"),
        "w_x": layers.dense_specs("ff", "ff"),
        "lam": ("ff",),
        "out": layers.dense_specs("ff", "embed"),
    }


def _causal_conv(p, x):
    """Depthwise causal conv via shifted adds. x: [b, s, r]."""
    w = p["conv_w"].astype(x.dtype)
    y = x * w[-1]
    for i in range(1, w.shape[0]):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[-1 - i]
    return y + p["conv_b"].astype(x.dtype)


def _gates(p, u):
    rf = jax.nn.sigmoid(layers.dense(p["w_a"], u, jnp.float32))
    i = jax.nn.sigmoid(layers.dense(p["w_x"], u, jnp.float32))
    log_a_hat = -jax.nn.softplus(p["lam"])           # [r], < 0
    log_a = C_SCALE * rf * log_a_hat                 # [b, s, r]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * (i * u.astype(jnp.float32))
    return a, gated


def forward(p, cfg: ArchConfig, x, positions=None):
    """x: [b, s, d] -> [b, s, d] (train/prefill)."""
    del positions
    gate = jax.nn.gelu(layers.dense(p["in_gate"], x))
    u = _causal_conv(p, layers.dense(p["in_rnn"], x))
    a, b = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    out = h.astype(x.dtype) * gate
    return layers.dense(p["out"], out)


# ------------------------------ decode path ---------------------------------


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    r = cfg.rnn_dim
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    }


def cache_specs(cfg: ArchConfig):
    return {"h": ("batch", "ff"), "conv": ("batch", None, "ff")}


def decode_step(p, cfg: ArchConfig, cache, x, pos=None):
    """x: [b, 1, d]. Returns (out [b,1,d], new_cache)."""
    del pos
    gate = jax.nn.gelu(layers.dense(p["in_gate"], x))
    u_in = layers.dense(p["in_rnn"], x)[:, 0]                    # [b, r]
    w = p["conv_w"].astype(u_in.dtype)
    hist = cache["conv"]                                         # [b, cw-1, r]
    u = u_in * w[-1] + jnp.einsum("bir,ir->br", hist.astype(u_in.dtype), w[:-1])
    u = u + p["conv_b"].astype(u.dtype)
    a, b = _gates(p, u[:, None])
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = h[:, None].astype(x.dtype) * gate
    new_conv = jnp.concatenate([hist[:, 1:], u_in[:, None].astype(hist.dtype)], axis=1)
    return layers.dense(p["out"], out), {"h": h, "conv": new_conv}
