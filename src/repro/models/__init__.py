from .model import Model
from . import attention, blocks, layers, moe, rglru, rwkv6

__all__ = ["Model", "attention", "blocks", "layers", "moe", "rglru", "rwkv6"]
