"""The unified decoder-only model over all assigned architectures.

Pure-function API (pjit-friendly):
    m = Model(cfg)
    params = m.init(rng)                      # eval_shape-able
    logits = m.forward(params, batch)
    loss, metrics = m.loss(params, batch)
    cache = m.init_cache(batch_size, cache_len)
    logits, cache = m.decode_step(params, cache, tokens, pos)

Modality frontends are stubs per the assignment: pixtral consumes
precomputed patch embeddings (projected + prepended to the text sequence),
musicgen consumes precomputed EnCodec code ids (vocab 2048).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import blocks, layers, xent

Constrain = Callable[[jax.Array, str], jax.Array]
_IDENT: Constrain = lambda x, name: x


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    constrain: Constrain = _IDENT          # activation sharding hook
    remat: str = "full"                    # full | dots | dots_no_batch | none
    aux_loss_weight: float = 0.01
    xent_chunk: int = 512                  # sequence chunk for the CE loss
    mesh: Any = None                       # enables shard_map paths (MoE EP)

    # ------------------------------ params ---------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        p: Dict[str, Any] = {
            "embed": layers.embed_init(ks[0], cfg.vocab, cfg.d_model),
            "stack": blocks.stack_init(ks[1], cfg),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.dense_init(ks[2], cfg.d_model, cfg.vocab)
        if cfg.frontend == "vision":
            p["patch_proj"] = layers.dense_init(ks[3], cfg.d_patch, cfg.d_model)
        return p

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        p: Dict[str, Any] = {
            "embed": layers.embed_specs(),
            "stack": blocks.stack_specs(cfg),
            "final_norm": layers.rmsnorm_specs(),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.dense_specs("embed", "vocab")
        if cfg.frontend == "vision":
            p["patch_proj"] = layers.dense_specs(None, "embed")
        return p

    # ----------------------------- forward ---------------------------------

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], batch["tokens"])
        if cfg.frontend == "vision" and "patches" in batch:
            pe = layers.dense(params["patch_proj"], batch["patches"])
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    def forward(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits [b, s_total, vocab], aux_loss)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = self.constrain(x, "hidden")
        x, aux = blocks.stack_apply(
            params["stack"], cfg, x, positions,
            constrain=self.constrain, remat=self.remat, mesh=self.mesh)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], x)
        else:
            logits = layers.dense(params["lm_head"], x)
        return self.constrain(logits, "logits"), aux

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Causal LM loss with sequence-chunked cross-entropy (models/xent.py)
        so the [b, s, V] logits are never fully materialized.
        batch needs tokens/targets (+optional loss_mask)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = self.constrain(x, "hidden")
        x, aux = blocks.stack_apply(
            params["stack"], cfg, x, positions,
            constrain=self.constrain, remat=self.remat, mesh=self.mesh)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        targets = batch["targets"]
        # vision prefix: hidden covers [patches|text]; targets cover text only
        if x.shape[1] != targets.shape[1]:
            x = x[:, x.shape[1] - targets.shape[1]:]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(targets.shape, jnp.float32)
        mask = mask.astype(jnp.float32)
        if cfg.tie_embeddings:
            w = params["embed"]["table"].T
        else:
            w = params["lm_head"]["w"]
        ce_sum, n = xent.chunked_xent(
            x, w, targets, mask, chunk=self.xent_chunk,
            constrain=self.constrain)
        ce = ce_sum / jnp.maximum(n, 1.0)
        total = ce + self.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    # ------------------------------ decode ---------------------------------

    def init_cache(self, batch: int, cache_len: int):
        return blocks.stack_cache_init(self.cfg, batch, cache_len)

    def cache_specs(self):
        return blocks.stack_cache_specs(self.cfg)

    def decode_step(self, params, cache, tokens, pos):
        """tokens: [b] int32 (next token ids); pos: [b] absolute positions.

        Returns (logits [b, vocab], new_cache).
        """
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], tokens[:, None])
        x = self.constrain(x, "decode_hidden")
        x, new_cache = blocks.stack_decode(params["stack"], cfg, cache, x, pos,
                                           mesh=self.mesh)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], x)
        else:
            logits = layers.dense(params["lm_head"], x)
        return logits[:, 0], new_cache
