"""GQA/MQA attention with RoPE, optional qk-norm and local windows.

Train/prefill path computes full (or banded) attention; the decode path
consumes a KV cache: global attention keeps a [B, cache_len, kv, hd] cache,
local attention keeps a ring buffer of ``window`` slots (so recurrentgemma's
long_500k decode state stays O(window), see DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import flash, layers

NEG = jnp.float32(-1e30)  # large-negative instead of -inf: keeps softmax NaN-free


def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    p = {
        "wq": layers.dense_init(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.use_bias),
        "wk": layers.dense_init(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.use_bias),
        "wv": layers.dense_init(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.use_bias),
        "wo": layers.dense_init(ks[3], cfg.q_dim, cfg.d_model, bias=cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def specs(cfg: ArchConfig):
    p = {
        "wq": layers.dense_specs("embed", "q_proj", bias=cfg.use_bias),
        "wk": layers.dense_specs("embed", "kv_proj", bias=cfg.use_bias),
        "wv": layers.dense_specs("embed", "kv_proj", bias=cfg.use_bias),
        "wo": layers.dense_specs("q_proj", "embed", bias=cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def _qkv(p, cfg: ArchConfig, x, positions):
    b, s, _ = x.shape
    q = layers.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = layers.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.head_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.head_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, mask, n_kv: int):
    """q: [b,sq,hq,hd]; k/v: [b,sk,kv,hd]; mask: [b,1,sq,sk] bool."""
    b, sq, hq, hd = q.shape
    group = hq // n_kv
    qg = q.reshape(b, sq, n_kv, group, hd)
    logits = jnp.einsum("bsngh,btnh->bngst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    logits = jnp.where(mask[:, :, None], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq * hd).astype(q.dtype)


FLASH_MIN_SEQ = 2048  # below this the full-matrix path is cheaper


def forward(p, cfg: ArchConfig, x, positions, *, window: Optional[int] = None,
            kv_chunk: int = 512, constrain=lambda x, name: x):
    """Full-sequence (train / prefill) attention. Sequences >= FLASH_MIN_SEQ
    use the chunked online-softmax path (models/flash.py) so the [s, s]
    score matrix is never materialized. GQA K/V are pre-expanded to flat
    q-heads so the head axis shards cleanly over the mesh model axis."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if s >= FLASH_MIN_SEQ:
        group = cfg.n_heads // cfg.n_kv_heads
        if group > 1:
            k = jnp.repeat(k, group, axis=2)
            v = jnp.repeat(v, group, axis=2)
        q = constrain(q, "attn_heads")
        k = constrain(k, "attn_heads")
        v = constrain(v, "attn_heads")
        out = flash.flash_attend(q, k, v, positions, positions, window,
                                 kv_chunk)
        out = out.reshape(b, s, cfg.q_dim).astype(x.dtype)
    else:
        pos_q = positions[:, :, None]           # [b,s,1]
        pos_k = positions[:, None, :]           # [b,1,s]
        mask = pos_k <= pos_q                   # causal
        if window is not None:
            mask = mask & (pos_k > pos_q - window)
        out = _attend(q, k, v, mask[:, None], cfg.n_kv_heads)
    return layers.dense(p["wo"], out)


# ------------------------------ decode path ---------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: Optional[int] = None, dtype=jnp.bfloat16):
    slots = min(window, cache_len) if window is not None else cache_len
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        # absolute position stored in each slot (-1 = empty)
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def cache_specs(cfg: ArchConfig):
    return {"k": ("batch", "cache_seq", "kv_heads", None),
            "v": ("batch", "cache_seq", "kv_heads", None),
            "pos": ("batch", "cache_seq")}


def decode_step(p, cfg: ArchConfig, cache, x, pos, *,
                window: Optional[int] = None):
    """One-token decode. x: [b,1,d]; pos: [b] absolute position.

    Global attention writes slot ``pos``; local attention writes ring slot
    ``pos % window``. Masking uses per-slot absolute positions, so both
    cases share one attend path.
    """
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    slots = cache["k"].shape[1]
    slot = (pos % slots).astype(jnp.int32)
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    cpos = cache["pos"].at[bidx, slot].set(pos.astype(jnp.int32))
    mask = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= pos[:, None, None])
    if window is not None:
        mask = mask & (cpos[:, None, :] > pos[:, None, None] - window)
    out = _attend(q, ck, cv, mask[:, None], cfg.n_kv_heads)
    out = layers.dense(p["wo"], out)
    return out, {"k": ck, "v": cv, "pos": cpos}
