"""Memory-efficient (flash) attention in pure XLA, with causal block
skipping: a scan over the *static list of live (q-block, kv-block) pairs*.

For causal attention only n(n+1)/2 of the n^2 block pairs are live; for
windowed attention only ~(window/block + 1) pairs per q block. Dead blocks
are never computed (the paper's "thread stops scanning past t_high"
transplanted to attention tiling — compare kernels/episode_track.py's
scalar-prefetched window tiles). The backward pass recomputes per-pair
scores (custom_vjp), so neither direction materializes [sq, sk].

This is the XLA-expressible twin of kernels/flash_attention.py (the Pallas
kernel used on real hardware). Layout: q/k/v [b, s, h, hd] with FLAT heads
— GQA is pre-expanded by the caller so the head axis shards cleanly over
the mesh model axis. Softmax statistics fp32; the P tile feeds the PV
matmul in bf16 (FlashAttention-2 discipline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG = jnp.float32(-1e30)


def _pick_chunk(s: int, want: int) -> int:
    c = min(want, s)
    while s % c:
        c -= 1
    return c


def _live_pairs(nq: int, nk: int, qc: int, kc: int,
                window: Optional[int], causal: bool = True):
    """Static list of (q_block, kv_block) pairs that can contain unmasked
    entries. Causal: kv start <= q end. Window: kv end > q start - window."""
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * qc, qi * qc + qc - 1
        for ki in range(nk):
            k_lo, k_hi = ki * kc, ki * kc + kc - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi <= q_lo - window:
                continue
            pairs.append((qi, ki))
    return pairs


def _block_mask(pos_qc, pos_kc, window):
    # pos_qc: [b, QC]; pos_kc: [b, KC] -> [b, 1, QC, KC]
    m = pos_kc[:, None, None, :] <= pos_qc[:, None, :, None]
    if window is not None:
        m = m & (pos_kc[:, None, None, :] > pos_qc[:, None, :, None] - window)
    return m


def _chunk(x, n, c):
    # [b, s, ...] -> [n, b, c, ...]
    b = x.shape[0]
    return jnp.moveaxis(x.reshape((b, n, c) + x.shape[2:]), 1, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attend(q, k, v, pos_q, pos_k, window: Optional[int],
                 kv_chunk: int = 512):
    out, _ = _flash_fwd_impl(q, k, v, pos_q, pos_k, window, kv_chunk)
    return out


def _prep(q, k, v, pos_q, pos_k, chunk):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qc = _pick_chunk(sq, chunk)
    kc = _pick_chunk(sk, chunk)
    nq, nk = sq // qc, sk // kc
    qs = _chunk(q.astype(jnp.float32), nq, qc)     # [nq, b, qc, h, hd]
    ks = _chunk(k.astype(jnp.float32), nk, kc)
    vs = _chunk(v.astype(jnp.float32), nk, kc)
    pq = _chunk(pos_q, nq, qc)                     # [nq, b, qc]
    pk = _chunk(pos_k, nk, kc)
    return qs, ks, vs, pq, pk, (nq, nk, qc, kc)


def _flash_fwd_impl(q, k, v, pos_q, pos_k, window, kv_chunk):
    b, sq, h, hd = q.shape
    scale = hd ** -0.5
    qs, ks, vs, pq, pk, (nq, nk, qc, kc) = _prep(q, k, v, pos_q, pos_k, kv_chunk)
    pairs = _live_pairs(nq, nk, qc, kc, window)
    qis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kis = jnp.asarray([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((nq, b, h, qc), NEG, jnp.float32)
    l0 = jnp.zeros((nq, b, h, qc), jnp.float32)
    a0 = jnp.zeros((nq, b, qc, h, hd), jnp.float32)

    def body(carry, pair):
        m, l, acc = carry
        qi, ki = pair
        q_c = lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        k_c = lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
        v_c = lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)
        pq_c = lax.dynamic_index_in_dim(pq, qi, 0, keepdims=False)
        pk_c = lax.dynamic_index_in_dim(pk, ki, 0, keepdims=False)
        logits = jnp.einsum("bshd,bthd->bhst", q_c, k_c) * scale
        mask = _block_mask(pq_c, pk_c, window)
        logits = jnp.where(mask, logits, NEG)
        m_prev = lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_prev = lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_prev = lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.where(mask, jnp.exp(logits - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(jnp.bfloat16),
                        v_c.astype(jnp.bfloat16)).astype(jnp.float32)
        a_new = a_prev * jnp.swapaxes(corr, 1, 2)[..., None] + pv
        m = lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (qis, kis))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / jnp.swapaxes(l_safe, 2, 3)[..., None]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)
    lse = jnp.moveaxis(m + jnp.log(l_safe), 0, 1)       # [b, nq, h, qc]
    lse = jnp.moveaxis(lse, 2, 1).reshape(b, h, sq)     # [b, h, sq]
    return out, lse


def _flash_fwd(q, k, v, pos_q, pos_k, window, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, pos_q, pos_k, window, kv_chunk)
    return out, (q, k, v, pos_q, pos_k, out, lse)


def _flash_bwd(window, kv_chunk, res, dout):
    q, k, v, pos_q, pos_k, out, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    qs, ks, vs, pq, pk, (nq, nk, qc, kc) = _prep(q, k, v, pos_q, pos_k, kv_chunk)
    pairs = _live_pairs(nq, nk, qc, kc, window)
    qis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kis = jnp.asarray([p[1] for p in pairs], jnp.int32)

    do = _chunk(dout.astype(jnp.float32), nq, qc)       # [nq, b, qc, h, hd]
    delta_full = jnp.swapaxes(
        jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1), 1, 2)
    dl = _chunk(jnp.swapaxes(delta_full, 1, 2)[..., None], nq, qc)[..., 0]
    dl = jnp.swapaxes(dl, 2, 3)                         # [nq, b, h, qc]
    lse_c = _chunk(jnp.swapaxes(lse, 1, 2)[..., None], nq, qc)[..., 0]
    lse_c = jnp.swapaxes(lse_c, 2, 3)                   # [nq, b, h, qc]

    dq0 = jnp.zeros((nq, b, qc, h, hd), jnp.float32)
    dk0 = jnp.zeros((nk, b, kc, h, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, kc, h, hd), jnp.float32)

    def body(carry, pair):
        dq, dk, dv = carry
        qi, ki = pair
        q_c = lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        k_c = lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
        v_c = lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)
        pq_c = lax.dynamic_index_in_dim(pq, qi, 0, keepdims=False)
        pk_c = lax.dynamic_index_in_dim(pk, ki, 0, keepdims=False)
        do_c = lax.dynamic_index_in_dim(do, qi, 0, keepdims=False)
        lse_b = lax.dynamic_index_in_dim(lse_c, qi, 0, keepdims=False)
        dl_b = lax.dynamic_index_in_dim(dl, qi, 0, keepdims=False)
        logits = jnp.einsum("bshd,bthd->bhst", q_c, k_c) * scale
        mask = _block_mask(pq_c, pk_c, window)
        p = jnp.where(mask, jnp.exp(logits - lse_b[..., None]), 0.0)
        pb = p.astype(jnp.bfloat16)
        dob = do_c.astype(jnp.bfloat16)
        dv_c = jnp.einsum("bhst,bshd->bthd", pb, dob).astype(jnp.float32)
        dp = jnp.einsum("bshd,bthd->bhst", do_c, v_c)
        ds = (p * (dp - dl_b[..., None]) * scale).astype(jnp.bfloat16)
        dq_c = jnp.einsum("bhst,bthd->bshd", ds,
                          k_c.astype(jnp.bfloat16)).astype(jnp.float32)
        dk_c = jnp.einsum("bhst,bshd->bthd", ds,
                          q_c.astype(jnp.bfloat16)).astype(jnp.float32)
        dq = dq.at[qi].add(dq_c)
        dk = dk.at[ki].add(dk_c)
        dv = dv.at[ki].add(dv_c)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = lax.scan(body, (dq0, dk0, dv0), (qis, kis))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, sk, h, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(b, sk, h, hd).astype(v.dtype)
    return dq, dk, dv, None, None


flash_attend.defvjp(_flash_fwd, _flash_bwd)


def attend_reference(q, k, v, pos_q, pos_k, window: Optional[int]):
    """Plain full-matrix attention (oracle / small-seq path).
    Same flat-head layout as flash_attend."""
    hd = q.shape[-1]
    scale = hd ** -0.5
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = (pos_k[:, None, None, :] <= pos_q[:, None, :, None])
    if window is not None:
        mask = mask & (pos_k[:, None, None, :]
                       > pos_q[:, None, :, None] - window)
    logits = jnp.where(mask, logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
