"""Multi-tenant streaming serving: one device-resident level loop for
thousands of concurrent append-only sessions (DESIGN.md §12).

The repo's two scale axes compose here. :class:`streaming.StreamingMiner`
(one stream, incremental appends) and :func:`corpus.mine_corpus` (many
streams, cold) each collapse their dimension into O(1) device programs —
but a serving process has BOTH dimensions live at once: many recording
sessions, each a growing stream, each wanting its full-stream result after
every chunk. Looping per-session miners pays the per-dispatch overhead
``S`` times per level; :class:`StreamingCorpusMiner` pays it once:

* **Session pool** — one ``[S, n_types, cap]`` per-type index pool holds
  every session's incremental index; all pending chunks scatter in ONE
  vmapped pass (:func:`events.type_index_update_batch`). Both the session
  axis and the shared per-type width are capacity classes
  (:func:`plan.capacity_class`), so ragged traffic — sessions of different
  ages, chunks of different sizes — reuses cached executables instead of
  recompiling mid-serve (the PR 4 lesson: per-unseen-shape recompiles
  dominate serving cost).

* **Grouped tail-delta flush** — per level, every dirty session's
  candidate frontier joins on host and counts against the pool through
  :func:`counting.count_corpus_tail_grouped`: PER-SESSION candidate rows
  (``symbols[S, B, N]``, session ``i`` paired with its own frontier — so
  dispatched rows stay proportional to the pool's real work even when a
  thousand sessions' frontiers diverge; a shared union would count every
  key against every session), per-session suffix cutoffs
  (``t_tail_start[S]``), per-session greedy chain-state carries, one
  dispatch family for warm rows (tail recount) and one for cold rows
  (backfill) — the cold family is the same plan shape with the degenerate
  ``-inf`` cutoff and an occupancy-class tail (not the table cap). All
  parts fetch in ONE ``device_get`` per level, the same budget as every
  batch miner in the repo.

* **Sessions are bit-for-bit solo miners** — a pooled session's per-level
  results equal a standalone :class:`StreamingMiner` fed the same chunks:
  the chunk acceptance rule (:func:`streaming.clean_chunk`), the f32
  suffix-cutoff slack (:func:`streaming.suffix_cutoff`), and the chain
  cache (:class:`streaming._ChainState` warmth rule) are the same code,
  and the pool's extra padding (+inf table columns/rows, repeated
  candidate rows) is inert by the DESIGN.md §5 conventions.
  Differentially enforced across engines x interleavings x churn in
  ``tests/test_serving.py``.

:class:`MiningSessionServer` is the serving front-end on top: opaque
session ids over recycled pool slots (continuous-batching style — the
``launch/serve.py`` slot-per-request pattern, claimed for mining), with
``create_session`` / ``append`` / ``evict`` / ``results`` and the
``plans()``/``warm()`` startup protocol so a warmed server provably never
compiles mid-serve (``plan.cache_stats()`` misses stay 0 — asserted in
``benchmarks/bench_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import counting
from . import events as events_lib
from . import plan as plan_mod
from .mining import (_OVERFLOW_MSG, LevelArrays, MinerConfig, _prune_level,
                     generate_candidates_arrays)
from .streaming import _TAIL_SHORT_MSG, _ChainState, clean_chunk, suffix_cutoff


@dataclasses.dataclass
class _SlotState:
    """Host-side mining state of one live session (one pool slot).

    The per-slot twin of :class:`streaming.StreamingMiner`'s own fields:
    exact host count mirror, amortized-growth event buffers, per-level
    chain-state caches, and the per-session frequency threshold.
    """

    threshold: int
    counts: np.ndarray                      # int64[n_types] exact mirror
    buf_types: np.ndarray                   # host event copies (amortized)
    buf_times: np.ndarray
    n_events: int = 0
    last_time: float = -np.inf              # last ABSORBED event time
    pending_last: float = -np.inf           # last QUEUED event time
    seq: int = 0                            # flushes that absorbed data
    pending: List[Tuple[np.ndarray, np.ndarray]] = dataclasses.field(
        default_factory=list)
    cache: Dict[int, Dict[tuple, _ChainState]] = dataclasses.field(
        default_factory=dict)
    results: Optional[Dict[int, LevelArrays]] = None
    # scratch set by flush() between the scatter and the level loop
    t0: float = -np.inf

    @property
    def all_types(self) -> np.ndarray:
        return self.buf_types[:self.n_events]

    @property
    def all_times(self) -> np.ndarray:
        return self.buf_times[:self.n_events]


def _new_slot_state(n_types: int, threshold: int) -> _SlotState:
    return _SlotState(
        threshold=int(threshold),
        counts=np.zeros((n_types,), np.int64),
        buf_types=np.empty((1024,), np.int32),
        buf_times=np.empty((1024,), np.float32))


class StreamingCorpusMiner:
    """Device-resident session pool: batched incremental level-wise mining.

    Slot-indexed core (the front-end :class:`MiningSessionServer` maps
    session ids onto slots). ``open_slot``/``close_slot`` manage the pool,
    ``queue`` buffers validated chunks, and ``flush`` absorbs EVERY pending
    chunk in one batched level loop — O(levels) dispatches and host syncs
    for the whole pool, regardless of how many sessions appended.

    Args:
      n_types: shared event-type alphabet (level-1 results depend on it,
        so one pool serves one alphabet — same rule as ``mine_corpus``).
      cfg: the usual :class:`MinerConfig`; ``cfg.threshold`` is the
        default per-session threshold, ``cfg.cap`` seeds the initial
        per-type capacity (a growth hint, never a limit), ``cfg.mesh`` is
        rejected (the pool is single-device; shard POOLS, not slots).
      slots: initial slot-count hint (grows in capacity classes).
      initial_cap: overrides the initial per-type capacity.
      growth: per-type capacity growth factor (> 1).
    """

    def __init__(self, n_types: int, cfg: MinerConfig, *, slots: int = 1,
                 initial_cap: Optional[int] = None, growth: float = 2.0):
        if cfg.mesh is not None:
            raise ValueError("StreamingCorpusMiner is single-device; "
                             "cfg.mesh must be None")
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if n_types < 1:
            raise ValueError(f"n_types must be >= 1, got {n_types}")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.n_types = int(n_types)
        self.cfg = cfg
        self.growth = float(growth)
        if initial_cap is None:
            initial_cap = 256 if cfg.cap is None else cfg.cap
        self.cap = plan_mod.capacity_class(max(1, initial_cap))
        self.n_slots = plan_mod.capacity_class(slots)
        self.tables = jnp.full((self.n_slots, self.n_types, self.cap),
                               jnp.inf, jnp.float32)
        self.counts_dev = jnp.zeros((self.n_slots, self.n_types), jnp.int32)
        self._slots: List[Optional[_SlotState]] = [None] * self.n_slots
        self._free: List[int] = list(range(self.n_slots - 1, -1, -1))
        self._soiled: set = set()   # slots whose device rows hold old data

    # -- slot lifecycle ----------------------------------------------------

    def open_slot(self, *, threshold: Optional[int] = None) -> int:
        """Claim a slot (recycling freed ones; the pool doubles — one new
        capacity class, one new plan bucket — only when none is free)."""
        if not self._free:
            self._grow_slots()
        slot = self._free.pop()
        if slot in self._soiled:
            # recycled slot: wipe the previous tenant's device rows (host
            # state was dropped at close; fresh slots are already clean)
            self.tables = self.tables.at[slot].set(jnp.inf)
            self.counts_dev = self.counts_dev.at[slot].set(0)
            self._soiled.discard(slot)
        self._slots[slot] = _new_slot_state(
            self.n_types,
            self.cfg.threshold if threshold is None else threshold)
        return slot

    def close_slot(self, slot: int) -> None:
        """Free a slot: host state (pending included) is dropped now; the
        device rows are wiped lazily on recycle, so eviction costs no
        device work and a mid-level close cannot perturb other sessions."""
        self._slot_state(slot)
        self._slots[slot] = None
        self._free.append(slot)

    def live_slots(self) -> List[int]:
        return [i for i, st in enumerate(self._slots) if st is not None]

    def _slot_state(self, slot: int) -> _SlotState:
        if not (0 <= slot < self.n_slots) or self._slots[slot] is None:
            raise KeyError(f"slot {slot} is not open")
        return self._slots[slot]

    def _grow_slots(self) -> None:
        new_n = self.n_slots * 2
        pad = new_n - self.n_slots
        self.tables = jnp.concatenate(
            [self.tables, jnp.full((pad,) + self.tables.shape[1:], jnp.inf,
                                   jnp.float32)], axis=0)
        self.counts_dev = jnp.concatenate(
            [self.counts_dev, jnp.zeros((pad, self.n_types), jnp.int32)],
            axis=0)
        self._free.extend(range(new_n - 1, self.n_slots - 1, -1))
        self._slots.extend([None] * pad)
        self.n_slots = new_n

    # -- appends -----------------------------------------------------------

    def queue(self, slot: int, types, times) -> int:
        """Validate one chunk (eagerly — bad input must fail at the append
        call, not a later flush) and buffer it. Returns accepted events."""
        st = self._slot_state(slot)
        types, times = clean_chunk(types, times, self.n_types,
                                   st.pending_last)
        if types.size == 0:
            return 0
        st.pending.append((types, times))
        st.pending_last = float(times[-1])
        return int(types.size)

    def dirty_slots(self) -> List[int]:
        return [i for i, st in enumerate(self._slots)
                if st is not None and st.pending]

    # -- the batched absorb ------------------------------------------------

    def flush(self) -> None:
        """Absorb every pending chunk in ONE batched level loop.

        Chunks queued for one session coalesce into a single absorb —
        streaming results are chunking-invariant (the PR 5 differential
        property), so coalescing cannot change any session's results.
        """
        dirty = [(i, self._slots[i]) for i in self.dirty_slots()]
        if not dirty:
            return
        chunks: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for i, st in dirty:
            ty = np.concatenate([c[0] for c in st.pending])
            tm = np.concatenate([c[1] for c in st.pending])
            st.pending.clear()
            chunks[i] = (ty, tm)

        # 1) pool-wide incremental index: grow-if-needed (geometric, then
        # class-aligned — O(log n) recompiles over the pool's life), then
        # scatter every session's chunk in one vmapped pass
        old_counts_dev = self.counts_dev
        needed = 0
        for i, st in dirty:
            st.counts = st.counts + np.bincount(chunks[i][0],
                                                minlength=self.n_types)
            needed = max(needed, int(st.counts.max()))
        if needed > self.cap:
            new_cap = self.cap
            while new_cap < needed:
                new_cap = max(new_cap + 1, int(new_cap * self.growth))
            new_cap = plan_mod.capacity_class(new_cap)
            self.tables = plan_mod.pad_width(self.tables, new_cap, jnp.inf)
            self.cap = new_cap
        # chunk matrix [S, M]: M class-rounded so ragged chunk sizes reuse
        # O(log) scatter programs; idle slots ride all-padding rows (no-op)
        m_max = max(c[0].size for c in chunks.values())
        m_cls = plan_mod.capacity_class(m_max, floor=16)
        ty_mat = np.full((self.n_slots, m_cls), -1, np.int32)
        tm_mat = np.full((self.n_slots, m_cls), np.inf, np.float32)
        for i, (ty, tm) in chunks.items():
            ty_mat[i, :ty.size] = ty
            tm_mat[i, :tm.size] = tm
        self.tables, self.counts_dev = events_lib.type_index_update_batch(
            self.tables, self.counts_dev, ty_mat, tm_mat)

        # 2) per-session host bookkeeping + span-bounded suffix sizing
        tail_need = 16
        for i, st in dirty:
            ty, tm = chunks[i]
            if st.n_events + ty.size > st.buf_times.size:
                new_size = max(st.n_events + int(ty.size),
                               2 * st.buf_times.size)
                st.buf_types = np.concatenate(
                    [st.all_types,
                     np.empty((new_size - st.n_events,), np.int32)])
                st.buf_times = np.concatenate(
                    [st.all_times,
                     np.empty((new_size - st.n_events,), np.float32)])
            st.buf_types[st.n_events:st.n_events + ty.size] = ty
            st.buf_times[st.n_events:st.n_events + tm.size] = tm
            st.n_events += int(ty.size)
            st.last_time = float(tm[-1])
            st.seq += 1
            st.t0 = suffix_cutoff(self.cfg, float(tm[0]), float(tm[-1]))
            i0 = int(np.searchsorted(st.all_times, st.t0, side="left"))
            suffix = np.bincount(st.all_types[i0:], minlength=self.n_types)
            tail_need = max(tail_need, int(suffix.max()))
            self._soiled.add(i)
        # ONE shared tail width (the max session's need, class-rounded):
        # a wider-than-needed view only appends +inf columns — inert, so
        # every session's counts stay bit-for-bit its solo miner's
        tail_cap = plan_mod.capacity_class(tail_need, floor=16)

        self._mine_levels_pool(dirty, tail_cap, old_counts_dev)

    # -- level loop (each session mirrors streaming._mine_levels) ----------

    def _mine_levels_pool(self, dirty, tail_cap, old_counts_dev) -> None:
        cfg = self.cfg
        t0_vec = np.full((self.n_slots,), np.inf, np.float32)
        for i, st in dirty:
            t0_vec[i] = st.t0
        results: Dict[int, Dict[int, LevelArrays]] = {}
        frontier: Dict[int, np.ndarray] = {}
        running: Dict[int, bool] = {}
        for i, st in dirty:
            freq = np.nonzero(st.counts >= st.threshold)[0].astype(np.int32)
            results[i] = {1: _prune_level(freq, st.counts, self.n_types)}
            frontier[i] = freq[:, None]
            running[i] = True

        for level in range(2, cfg.max_level + 1):
            joined: Dict[int, np.ndarray] = {}
            for i, st in dirty:
                if not running[i]:
                    continue
                if frontier[i].shape[0] == 0:
                    running[i] = False                   # quiet: no record
                    continue
                cands = generate_candidates_arrays(frontier[i], level, cfg)
                if cands.shape[0] == 0:
                    results[i][level] = LevelArrays(
                        np.zeros((0, level), np.int32),
                        np.zeros((0,), np.int32), 0)
                    running[i] = False
                    continue
                joined[i] = cands
            if not joined:
                break
            counts_by_slot = self._count_level_pool(
                level, joined, t0_vec, tail_cap, old_counts_dev)
            override = (cfg.level_thresholds or {}).get(level)
            for i, cands in joined.items():
                st = self._slots[i]
                thr = st.threshold if override is None else override
                counts_h = counts_by_slot[i]
                keep = counts_h >= thr
                frontier[i] = cands[keep]
                results[i][level] = LevelArrays(
                    frontier[i], counts_h[keep].astype(np.int32),
                    cands.shape[0])

        for i, st in dirty:
            st.results = results[i]
            # evict chain states not advanced through THIS flush (the
            # streaming warmth rule: stale states can only recount cold)
            for cache in st.cache.values():
                stale = [k for k, cs in cache.items() if cs.seq != st.seq]
                for k in stale:
                    del cache[k]

    def _count_level_pool(self, level, joined, t0_vec, tail_cap,
                          old_counts_dev) -> Dict[int, np.ndarray]:
        """Count one level for every dirty session: grouped dispatches.

        Each dispatch pairs session ``i`` with ITS OWN candidate rows
        (``symbols[S, B, N]``, the :func:`counting.count_corpus_tail_grouped`
        layout) — dispatched rows stay proportional to the work the pool
        actually needs even when sessions' frontiers diverge (a shared
        union of 1k diverse frontiers would count every key against every
        session). Warmth is per (session, episode) — session A can be warm
        on a key session B first reached this flush — so warm
        (tail-recount) and cold (full-backfill) row families dispatch
        separately; the cold family's tail is the pool's occupancy class,
        not the table cap. Unused rows of shorter sessions are computed and
        never read (the ``mine_corpus`` quiet-stream masking rule), and all
        chunks of both families fetch in ONE ``device_get``.
        """
        cfg = self.cfg
        keys_of: Dict[int, list] = {}
        warm_rows: Dict[int, np.ndarray] = {}
        cold_rows: Dict[int, np.ndarray] = {}
        cold_need = 0
        for i, cands in joined.items():
            st = self._slots[i]
            cache = st.cache.setdefault(level, {})
            keys = [tuple(int(x) for x in row) for row in cands]
            keys_of[i] = keys
            warm = np.array(
                [cache.get(k) is not None and cache[k].seq == st.seq - 1
                 for k in keys], bool)
            warm_rows[i] = np.nonzero(warm)[0]
            cold_rows[i] = np.nonzero(~warm)[0]
            if cold_rows[i].size:
                # a cold backfill reads this session's whole per-type
                # history, so the cold tail must cover its max occupancy
                cold_need = max(cold_need, int(st.counts.max()))

        knobs = dict(
            engine=cfg.engine, cap_occ=cfg.cap_occ, max_window=cfg.max_window,
            parallel_schedule=cfg.parallel_schedule, block_next=cfg.block_next,
            block_prev=cfg.block_prev, window_tiles=cfg.window_tiles,
            interpret=cfg.interpret)
        chunk = max(cfg.max_candidates, 1)
        cold_tail = plan_mod.capacity_class(cold_need, floor=16)
        dispatched = []   # (rows_of, chunk parts)

        def family(rows_of, tail, t0, oc):
            """Dispatch one row family, chunked along the batch axis; all
            sessions advance through the chunks in lockstep (chunk k holds
            each session's rows [k*chunk, (k+1)*chunk) of the family)."""
            b_max = max(r.size for r in rows_of.values())
            if b_max == 0:
                return
            parts = []
            for start in range(0, b_max, chunk):
                # class-rounded chunk width (floor 16, the MAX_BATCH_PAD
                # discipline) so ragged last chunks reuse warmed buckets
                bc = plan_mod.capacity_class(
                    min(chunk, b_max - start), floor=16)
                sym = np.zeros((self.n_slots, bc, level), np.int32)
                pe = np.full((self.n_slots, bc), -np.inf, np.float32)
                pc = np.zeros((self.n_slots, bc), np.int32)
                sel = {}
                for i, rows in rows_of.items():
                    rows = rows[start:start + chunk]
                    if rows.size == 0:
                        continue
                    sel[i] = rows
                    sym[i, :rows.size] = joined[i][rows]
                    if oc is None:      # warm family: carried greedy state
                        cache = self._slots[i].cache[level]
                        for j, r in enumerate(rows):
                            cs = cache[keys_of[i][r]]
                            pe[i, j] = cs.prev_end
                            pc[i, j] = cs.count
                lo = np.full((bc, level - 1), cfg.t_low, np.float32)
                hi = np.full((bc, level - 1), cfg.t_high, np.float32)
                parts.append((sel, counting.count_corpus_tail_grouped(
                    self.tables, self.counts_dev,
                    old_counts_dev if oc is None else oc,
                    t0, sym, lo, hi, pe, pc, tail_cap=tail, **knobs)))
            dispatched.append(parts)

        family(warm_rows, tail_cap, t0_vec, None)
        # the degenerate tail: -inf cutoff + zero old_counts + an
        # occupancy-wide view == full stateful backfill, fresh carries
        family(cold_rows, cold_tail,
               np.full((self.n_slots,), -np.inf, np.float32),
               np.zeros((self.n_slots, self.n_types), np.int32))

        fetched = jax.device_get(
            [[p[1] for p in parts] for parts in dispatched])      # ONE sync
        out: Dict[int, np.ndarray] = {
            i: np.zeros((len(keys_of[i]),), np.int64) for i in joined}
        for parts, vals in zip(dispatched, fetched):
            for (sel, _), (cnt, end, _, ovf, short) in zip(parts, vals):
                for i, rows in sel.items():
                    m = rows.size
                    if short[i, :m].any():
                        raise RuntimeError(_TAIL_SHORT_MSG)
                    if ovf[i, :m].any():
                        raise RuntimeError(
                            f"session slot {i}: {_OVERFLOW_MSG}")
                    st = self._slots[i]
                    cache = st.cache[level]
                    out[i][rows] = cnt[i, :m]
                    for j, r in enumerate(rows):
                        cache[keys_of[i][r]] = _ChainState(
                            prev_end=float(end[i, j]),
                            count=int(cnt[i, j]), seq=st.seq)
        return out

    # -- results / warm protocol -------------------------------------------

    def slot_results(self, slot: int) -> Dict[int, LevelArrays]:
        """This slot's per-level result. Flushes the WHOLE pool first if
        anything (any session) is pending — one batched absorb, not a
        private one. A never-appended session reports its (empty) level-1
        truthfully without touching the device."""
        st = self._slot_state(slot)
        if self.dirty_slots():
            self.flush()
        if st.results is None:
            # never-appended: the standalone cold `.results` path — mine
            # from scratch (all-cold, -inf cutoff; with any positive
            # threshold this records empty level 1 without device work)
            self._mine_levels_pool([(slot, st)], tail_cap=16,
                                   old_counts_dev=self.counts_dev)
        return dict(st.results)

    def plans(self, *, batches=None, tail_caps=()) -> List[
            plan_mod.MiningPlan]:
        """Every ``count_corpus_tail_grouped`` plan a flush can dispatch
        at the pool's CURRENT capacity classes, for :func:`plan.warm`.

        ``batches`` defaults to every candidate-batch class up to
        ``class(min(max_candidates, n_types^2))`` (the same default as
        ``plan.plans_for_miner``). Tail classes are enumerated completely:
        every flush tail — warm suffix need or cold occupancy — is class
        16..``cap``, so the default set covers every tail bucket this pool
        can ever dispatch (``tail_caps`` stays accepted for callers that
        want extra widths, e.g. ahead of a planned cap growth).
        """
        cfg = self.cfg
        if batches is None:
            top = plan_mod.capacity_class(
                min(cfg.max_candidates, self.n_types * self.n_types))
            batches = []
            b = 16
            while b <= top:
                batches.append(b)
                b *= 2
            batches = batches or [top]
        batches = sorted({plan_mod.pow2_ceil(int(b)) for b in batches})
        tcs = {plan_mod.capacity_class(int(t), floor=16) for t in tail_caps}
        t = 16
        while t <= self.cap:
            tcs.add(t)
            t *= 2
        tcs = sorted(tcs)
        knobs = dict(
            n_types=self.n_types, cap=self.cap, streams=self.n_slots,
            engine=cfg.engine, parallel_schedule=cfg.parallel_schedule,
            cap_occ=cfg.cap_occ, max_window=cfg.max_window,
            block_next=cfg.block_next, block_prev=cfg.block_prev,
            window_tiles=cfg.window_tiles, interpret=cfg.interpret)
        return [plan_mod.plan_for("count_corpus_tail_grouped", level=level,
                                  batch=b, tail_cap=tc, **knobs)
                for level in range(2, cfg.max_level + 1)
                for b in batches for tc in tcs]

    def warm(self, *, batches=None, tail_caps=()) -> Dict[str, int]:
        """Precompile this pool's plans (serving-startup protocol): a
        warmed pool whose capacities don't grow mid-serve pays ZERO
        compiles — and zero plan-cache misses — on live traffic."""
        return plan_mod.warm(self.plans(batches=batches,
                                        tail_caps=tail_caps))


class MiningSessionServer:
    """Session front-end over a :class:`StreamingCorpusMiner` pool.

    Opaque monotonically-increasing session ids map onto recycled pool
    slots (continuous-batching style: an evicted session frees its slot
    for the next ``create_session``; the pool only grows — one capacity
    class at a time — when every slot is live). Appends buffer per
    session and the next ``flush()`` (or any ``results()`` read) absorbs
    ALL of them in one batched device pass.

    The API a serving process needs and nothing else:
    ``create_session() -> sid``, ``append(sid, types, times)``,
    ``evict(sid)``, ``results(sid)``, plus ``flush()`` for explicit batch
    boundaries and ``plans()``/``warm()`` for the startup compile.
    """

    def __init__(self, n_types: int, cfg: MinerConfig, *,
                 max_sessions: int = 1, initial_cap: Optional[int] = None,
                 growth: float = 2.0):
        self.pool = StreamingCorpusMiner(
            n_types, cfg, slots=max_sessions, initial_cap=initial_cap,
            growth=growth)
        self._slot_of: Dict[int, int] = {}
        self._next_sid = 0

    # -- sessions ----------------------------------------------------------

    def create_session(self, *, threshold: Optional[int] = None) -> int:
        """Open a session; returns its id (never reused, unlike slots)."""
        slot = self.pool.open_slot(threshold=threshold)
        sid = self._next_sid
        self._next_sid += 1
        self._slot_of[sid] = slot
        return sid

    def append(self, sid: int, types, times) -> int:
        """Validate and buffer one chunk for ``sid`` (absorbed at the next
        flush). Returns the number of accepted (non-padding) events."""
        return self.pool.queue(self._slot(sid), types, times)

    def evict(self, sid: int) -> None:
        """End a session: drop its state (pending included) and recycle
        its slot. Further ``append``/``results`` calls for ``sid`` raise."""
        slot = self._slot(sid)
        del self._slot_of[sid]
        self.pool.close_slot(slot)

    def results(self, sid: int) -> Dict[int, LevelArrays]:
        """``sid``'s full-stream per-level result — bit-for-bit what a
        standalone ``StreamingMiner`` fed the same chunks returns.
        Triggers a pool flush if any session has pending chunks."""
        return self.pool.slot_results(self._slot(sid))

    def _slot(self, sid: int) -> int:
        if sid not in self._slot_of:
            raise KeyError(f"session {sid} does not exist (evicted?)")
        return self._slot_of[sid]

    def __len__(self) -> int:
        return len(self._slot_of)

    # -- pool passthrough --------------------------------------------------

    def flush(self) -> None:
        """Absorb every session's pending chunks in one batched pass."""
        self.pool.flush()

    def plans(self, **kw):
        return self.pool.plans(**kw)

    def warm(self, **kw):
        return self.pool.warm(**kw)
