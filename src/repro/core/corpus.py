"""Multi-stream batched mining: one level loop for a whole corpus (§II-C at
corpus scale).

The paper's transformation counts one spike-train at a time, but its
intended users analyze corpora — many recordings/trials per experiment
(cf. *Towards Chip-on-Chip Neuroscience*). :func:`mine_corpus` runs the
Apriori level loop ONCE for a padded batch of ``S`` independent streams:

* the per-stream type indexes are built in one vmapped device pass
  (:func:`events.type_index_batch`); ragged stream lengths cost ``+inf``
  padding inside the shared capacity, never extra launches or recompiles;
* per level, every stream's candidate frontier is joined on host (compact
  numpy, exactly :func:`mining.generate_candidates_arrays` per stream), the
  frontiers are deduplicated into one *union* candidate batch, and that
  union is counted against every stream through a single dispatch
  (:func:`counting.count_corpus_indexed` — with a corpus-native engine the
  whole ``S x B`` grid is ONE fused kernel launch, the stream axis folded
  into the batch grid dimension);
* per-stream thresholds are applied on device (``keep`` masks ride back in
  the same transfer), so each level pays exactly ONE host sync for the
  whole corpus;
* streams whose frontier empties go *quiet*: they stop contributing
  candidates and their rows of the fetched arrays are masked on host —
  never branched on device (static shapes, no recompiles) and never given
  an extra sync. A quiet stream's overflow flags are masked too: it counts
  nothing, so it can overflow nothing (matching its solo run).

Results are bit-for-bit identical to ``[mine_arrays(s) for s in streams]``
— tracking, scheduling, and overflow are per-(stream, episode)-row, so
batch composition cannot perturb them (differentially tested, including
the golden fixture).

Aggregation modes: ``per_stream`` (the list of per-stream frequent sets)
always; ``corpus`` ("frequent in >= m streams") when ``min_streams`` is
given — per level, the episodes frequent in at least ``m`` streams, with
``counts`` = the number of supporting streams (support, not occurrence
totals: corpora mix trials of different lengths, so occurrence sums would
be dominated by the longest recording).

With ``cfg.mesh`` set the stream axis is sharded across the mesh
(:func:`distributed.count_corpus_sharded_indexed`): streams are
independent, so no halo exchange and no cross-shard merge exist at all —
the embarrassingly-parallel fast path (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import counting, distributed
from . import events as events_lib
from .events import EventStream
from .mining import (_OVERFLOW_MSG, LevelArrays, MinerConfig, _prune_level,
                     generate_candidates_arrays, pad_candidate_rows)


@dataclasses.dataclass
class CorpusResult:
    """Per-stream frequent sets plus the optional corpus-level aggregate."""

    per_stream: List[Dict[int, LevelArrays]]
    corpus: Optional[Dict[int, LevelArrays]] = None


def pad_corpus(
    streams: Sequence[EventStream],
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Stack a ragged corpus into padded ``[S, L]`` arrays.

    Types pad with ``-1`` (dropped by the type index), times with ``+inf``
    (inert under every downstream max/searchsorted). All streams must share
    one event-type alphabet — level-1 results depend on ``n_types``, so a
    mixed corpus cannot match its per-stream runs.

    Returns ``(types i32[S, L], times f32[S, L], n_types)``.
    """
    if not streams:
        raise ValueError("mine_corpus needs at least one stream")
    alphabet = {s.n_types for s in streams}
    if len(alphabet) != 1:
        raise ValueError(
            f"corpus streams must share one n_types, got {sorted(alphabet)}")
    n_types = alphabet.pop()
    length = max(1, max(s.n_events for s in streams))
    types = np.full((len(streams), length), -1, np.int32)
    times = np.full((len(streams), length), np.inf, np.float32)
    for i, s in enumerate(streams):
        n = s.n_events
        types[i, :n] = np.asarray(s.types)
        times[i, :n] = np.asarray(s.times)
    return types, times, n_types


def _level_thresholds(
    thresholds: np.ndarray, level: int, cfg: MinerConfig
) -> np.ndarray:
    """Per-stream thresholds for one level: a per-level override (shared —
    it is a property of the level, not the stream) beats the per-stream
    base, exactly as ``mine_arrays`` resolves it per stream."""
    override = (cfg.level_thresholds or {}).get(level)
    if override is not None:
        return np.full_like(thresholds, override)
    return thresholds


def union_candidates(frontiers: Sequence[np.ndarray]):
    """Dedup per-stream candidate frontiers into one union batch.

    ``frontiers`` are same-width ``i32[b_i, N]`` row blocks (one per
    running stream/session, in read-back order). Returns ``(union
    i32[U, N], inverse i32[sum b_i])`` where block ``i``'s rows map to
    ``union[inverse[offset_i : offset_i + b_i]]`` — each caller reads its
    own candidates' rows back out of the one counted union (the
    un-union convention shared by :func:`mine_corpus` and the serving
    session pool).
    """
    stacked = np.concatenate(list(frontiers), axis=0)
    union, inverse = np.unique(stacked, axis=0, return_inverse=True)
    return union.astype(np.int32), inverse.reshape(-1)


def aggregate_min_streams(
    per_stream: Sequence[Dict[int, LevelArrays]], min_streams: int
) -> Dict[int, LevelArrays]:
    """Corpus-level "frequent in >= m streams" aggregation.

    Per level: the union of per-stream frequent sets (each stream's rows
    are distinct, so concatenated multiplicity == supporting-stream count),
    kept when supported by at least ``min_streams`` streams. ``symbols``
    are in lexicographic row order (the union has no single discovery
    order); ``counts`` is the support; ``n_candidates`` is the union size
    before the support cut.
    """
    if min_streams < 1:
        raise ValueError(f"min_streams must be >= 1, got {min_streams}")
    out: Dict[int, LevelArrays] = {}
    levels = sorted({lvl for res in per_stream for lvl in res})
    for lvl in levels:
        rows = [res[lvl].symbols for res in per_stream
                if lvl in res and res[lvl].symbols.shape[0]]
        if not rows:
            out[lvl] = LevelArrays(
                np.zeros((0, lvl), np.int32), np.zeros((0,), np.int32), 0)
            continue
        stacked = np.concatenate(rows, axis=0)
        union, support = np.unique(stacked, axis=0, return_counts=True)
        keep = support >= min_streams
        out[lvl] = LevelArrays(
            union[keep].astype(np.int32), support[keep].astype(np.int32),
            union.shape[0])
    return out


def mine_corpus(
    streams: Sequence[EventStream],
    cfg: MinerConfig,
    *,
    thresholds: Optional[Sequence[int]] = None,
    min_streams: Optional[int] = None,
) -> CorpusResult:
    """Level-wise mining of ``S`` independent streams in one device loop.

    Args:
      streams: the corpus; ragged lengths and empty streams are fine (they
        pad, they don't launch). All must share one ``n_types``.
      cfg: the usual :class:`MinerConfig`; ``cfg.threshold`` is the default
        per-stream frequency threshold and ``cfg.mesh`` shards the *stream*
        axis (not the time axis — no halo, streams are independent).
      thresholds: optional per-stream threshold overrides, length ``S``.
      min_streams: enable the corpus-level ">= m streams" aggregate
        (defaults to ``cfg.min_streams``; ``None`` disables it).

    Returns a :class:`CorpusResult` whose ``per_stream[i]`` equals
    ``mine_arrays(streams[i], cfg_i)`` bit-for-bit (``cfg_i`` = ``cfg``
    with that stream's threshold).
    """
    n_streams = len(streams)
    types, times, n_types = pad_corpus(streams)
    if thresholds is None:
        thr_base = np.full((n_streams,), cfg.threshold, np.int32)
    else:
        thr_base = np.asarray(thresholds, np.int32)
        if thr_base.shape != (n_streams,):
            raise ValueError(
                f"thresholds must have shape ({n_streams},), got {thr_base.shape}")
    if min_streams is None:
        min_streams = cfg.min_streams
    # `is None`, not `or`: an explicit cap=0 must hit type_index's loud
    # ValueError, not silently widen to the padded corpus length
    cap = types.shape[1] if cfg.cap is None else cfg.cap

    if cfg.mesh is not None:
        index = distributed.build_corpus_index(
            types, times, cfg.mesh, axis=cfg.shard_axis, n_types=n_types,
            cap=cap)
        binc = np.asarray(index.type_counts)[:n_streams]  # level-1 host sync
        pad_rows = index.tables.shape[0] - n_streams

        def count_level(sym, lo, hi, thr):
            thr_padded = np.concatenate(
                [thr, np.zeros((pad_rows,), np.int32)])
            return distributed.count_corpus_sharded_indexed(
                index, sym, lo, hi, jnp.asarray(thr_padded),
                engine=cfg.engine, cap_occ=cfg.cap_occ,
                max_window=cfg.max_window,
                parallel_schedule=cfg.parallel_schedule,
                block_next=cfg.block_next, block_prev=cfg.block_prev,
                window_tiles=cfg.window_tiles, interpret=cfg.interpret)
    else:
        tables, type_counts = events_lib.type_index_batch(
            types, times, n_types, cap)                   # built ONCE
        binc = np.asarray(type_counts)[:n_streams]        # level-1 host sync
        # pad ONCE to the plan bucket: capacity class on the table width,
        # capacity class on the stream axis (all-+inf rows count nothing
        # and are sliced away below) — every level of every same-class
        # corpus then reuses ONE cached executable (plan.py). build_cap
        # pins overflow checks to the true build width.
        from . import plan as plan_mod
        tables = plan_mod.pad_width(
            tables, plan_mod.capacity_class(cap), jnp.inf)
        s_pad = plan_mod.capacity_class(n_streams) - n_streams
        if s_pad:
            tables = jnp.concatenate(
                [tables, jnp.full((s_pad,) + tables.shape[1:], jnp.inf,
                                  jnp.float32)], axis=0)
            type_counts = jnp.concatenate(
                [type_counts, jnp.zeros((s_pad, n_types), jnp.int32)], axis=0)

        def count_level(sym, lo, hi, thr):
            thr_padded = np.concatenate([thr, np.zeros((s_pad,), np.int32)])
            return counting.count_corpus_indexed(
                tables, type_counts, sym, lo, hi, jnp.asarray(thr_padded),
                engine=cfg.engine, cap_occ=cfg.cap_occ,
                max_window=cfg.max_window,
                parallel_schedule=cfg.parallel_schedule,
                block_next=cfg.block_next, block_prev=cfg.block_prev,
                window_tiles=cfg.window_tiles, interpret=cfg.interpret,
                build_cap=cap)

    # level 1: per-stream single-type episodes (one transfer did all S)
    results: List[Dict[int, LevelArrays]] = []
    frontier: List[np.ndarray] = []
    running = np.ones((n_streams,), bool)
    for s in range(n_streams):
        freq_types = np.nonzero(binc[s] >= thr_base[s])[0].astype(np.int32)
        results.append({1: _prune_level(freq_types, binc[s], n_types)})
        frontier.append(freq_types[:, None])

    for level in range(2, cfg.max_level + 1):
        # host-side joins: each running stream's own frontier, exactly the
        # per-stream join (order, truncation and all)
        joined: Dict[int, np.ndarray] = {}
        for s in range(n_streams):
            if not running[s]:
                continue
            if frontier[s].shape[0] == 0:
                running[s] = False                       # quiet: no record
                continue
            cands = generate_candidates_arrays(frontier[s], level, cfg)
            if cands.shape[0] == 0:
                results[s][level] = LevelArrays(
                    np.zeros((0, level), np.int32), np.zeros((0,), np.int32), 0)
                running[s] = False
                continue
            joined[s] = cands
        if not joined:
            break

        # union frontier: dedup across streams, count once for everyone.
        # The union can exceed cfg.max_candidates (it is a PER-STREAM valve
        # — up to S disjoint frontiers stack), so it is counted in chunks
        # of max_candidates: tracking is per-(stream, episode)-row, so
        # chunk boundaries cannot perturb results, and peak device memory
        # for the [S, chunk, N, cap] gather stays what a single stream's
        # worst-case level costs. All chunks' results are fetched in one
        # device_get — still exactly ONE host sync per level.
        union, inverse = union_candidates(list(joined.values()))
        n_union = union.shape[0]
        thr = _level_thresholds(thr_base, level, cfg)
        chunk = max(cfg.max_candidates, 1)
        parts = []
        for start in range(0, n_union, chunk):
            rows = union[start:start + chunk].astype(np.int32)
            sym, lo, hi = pad_candidate_rows(rows, level, cfg)
            counts_dev, keep_dev, _, overflow_dev = count_level(
                sym, lo, hi, thr)
            m = rows.shape[0]
            parts.append((counts_dev[:n_streams, :m],
                          keep_dev[:n_streams, :m],
                          overflow_dev[:n_streams, :m]))
        # staticcheck: disable=REPRO004 -- THE sanctioned one-sync-per-level
        fetched = jax.device_get(parts)
        counts_h = np.concatenate([p[0] for p in fetched], axis=1)
        keep_h = np.concatenate([p[1] for p in fetched], axis=1)
        overflow_h = np.concatenate([p[2] for p in fetched], axis=1)

        # un-union: each stream reads its own candidates' rows; quiet
        # streams' rows (and their flags) are masked by never being read
        offset = 0
        for s, cands in joined.items():
            idx = inverse[offset:offset + cands.shape[0]]
            offset += cands.shape[0]
            if bool(np.any(overflow_h[s, idx])):
                raise RuntimeError(f"stream {s}: {_OVERFLOW_MSG}")
            kept = keep_h[s, idx]
            frontier[s] = cands[kept]
            results[s][level] = LevelArrays(
                frontier[s],
                np.asarray(counts_h[s, idx])[kept].astype(np.int32),
                cands.shape[0])

    corpus = (aggregate_min_streams(results, min_streams)
              if min_streams is not None else None)
    return CorpusResult(per_stream=results, corpus=corpus)
