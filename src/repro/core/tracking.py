"""Parallel local tracking (paper §IV-C, Algorithm 2) — subproblem 1.

Given the per-type pre-index, find a superset of episode occurrences with
full data parallelism. Two engines:

``track_faithful_*`` — the paper's algorithm: one "thread" per current-level
  entry scans its constraint window and records *every* matching next event
  (duplicates kept), then the per-thread variable-length outputs are
  compacted (see core/compaction.py). ``_backward`` starts from the *last*
  symbol so the final occurrence list is automatically ordered by end time
  (paper §IV-E's sort-elimination trick); ``_forward`` is the variant whose
  output must be sorted (the AtomicCompact cost profile).

``track_dense`` — beyond-paper: per *event* (not per occurrence-path) keep
  only the latest start time of any partial occurrence ending at that event.
  Dominance argument: if two occurrences end at the same event, the one with
  the later start is contained in the other, so any non-overlapped set using
  the longer one remains valid after swapping in the shorter one. Hence one
  interval per reachable end event (with the latest start) preserves the
  maximum non-overlapped count, and each level reduces to a windowed
  range-max: searchsorted window bounds + an O(n log n) sparse-table max.
  Work is independent of episode frequency — this removes the superset
  blow-up the paper observes in Fig 12 — and no compaction step exists at
  all. Validated against the numpy oracle and the faithful engines.

All functions are static-shaped: event tables are ``+inf``-padded, value
(latest-start) tables are ``-inf``-padded.

Engines are exposed through a registry (see :class:`TrackingEngine` and
:func:`register_engine` at the bottom of this module): ``counting.py``
dispatches by name, so adding an engine is one ``register_engine`` call —
no if/elif ladder to extend. The ``dense_pallas`` engine drives the Pallas
TPU kernel (``kernels/episode_track.py``) through ``kernels/ops.py``,
falling back to interpret mode off-TPU (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from . import compaction

NEG = -jnp.inf


class Occurrences(NamedTuple):
    """A padded set of candidate occurrence intervals, plus tracking stats."""

    starts: jax.Array   # f32[cap] (-inf padding)
    ends: jax.Array     # f32[cap] (+inf padding)
    valid: jax.Array    # bool[cap]
    n_superset: jax.Array  # i32 — total (possibly overlapping) occurrences tracked
    overflow: jax.Array    # bool — capacity exceeded somewhere (count unsafe)


# ---------------------------------------------------------------------------
# Sparse-table range maximum (shared with kernels/ref.py)
# ---------------------------------------------------------------------------


def build_sparse_table(v: jax.Array) -> jax.Array:
    """Stacked doubling max table M[k, i] = max(v[i : i+2^k]); [K, cap]."""
    cap = v.shape[-1]
    levels = [v]
    k = 1
    while (1 << k) <= max(cap, 1):
        half = 1 << (k - 1)
        prev = levels[-1]
        shifted = jnp.concatenate(
            [prev[..., half:], jnp.full(prev.shape[:-1] + (half,), NEG, prev.dtype)],
            axis=-1,
        )
        levels.append(jnp.maximum(prev, shifted))
        k += 1
    return jnp.stack(levels, axis=0)


def range_max(table: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Vectorized max(v[lo:hi]) queries; -inf where hi <= lo."""
    cap = table.shape[-1]
    length = jnp.clip(hi - lo, 0, cap)
    # floor(log2(L)) via frexp (exact for L < 2^24)
    _, exp = jnp.frexp(jnp.maximum(length, 1).astype(jnp.float32))
    k = (exp - 1).astype(jnp.int32)
    pow2 = jnp.left_shift(jnp.int32(1), k)
    lo_c = jnp.clip(lo, 0, cap - 1)
    hi_c = jnp.clip(hi - pow2, 0, cap - 1)
    a = table[k, lo_c]
    b = table[k, hi_c]
    return jnp.where(length > 0, jnp.maximum(a, b), NEG)


# ---------------------------------------------------------------------------
# Dense (beyond-paper) tracking
# ---------------------------------------------------------------------------


def track_dense(
    times_by_sym: jax.Array,  # f32[N, cap] sorted rows, +inf padded
    t_low: jax.Array,         # f32[N-1]
    t_high: jax.Array,        # f32[N-1]
) -> Occurrences:
    n = times_by_sym.shape[0]
    t0 = times_by_sym[0]
    value = jnp.where(jnp.isfinite(t0), t0, NEG)   # latest start per event
    n_superset = jnp.sum(jnp.isfinite(t0)).astype(jnp.int32)
    for i in range(n - 1):
        t_prev = times_by_sym[i]
        t_next = times_by_sym[i + 1]
        # valid prev times s for next time t:  t - hi <= s < t - lo
        lo_idx = jnp.searchsorted(t_prev, t_next - t_high[i], side="left")
        hi_idx = jnp.searchsorted(t_prev, t_next - t_low[i], side="left")
        table = build_sparse_table(value)
        value = range_max(table, lo_idx.astype(jnp.int32), hi_idx.astype(jnp.int32))
        value = jnp.where(jnp.isfinite(t_next), value, NEG)
        n_superset = n_superset + jnp.sum(value > NEG).astype(jnp.int32)
    ends = times_by_sym[n - 1]
    valid = (value > NEG) & jnp.isfinite(ends)
    return Occurrences(
        starts=value,
        ends=jnp.where(valid, ends, jnp.inf),
        valid=valid,
        n_superset=n_superset,
        overflow=jnp.bool_(False),
    )


# ---------------------------------------------------------------------------
# Faithful tracking (paper Algorithm 2) with pluggable compaction
# ---------------------------------------------------------------------------


def _window_bounds_backward(t_prevsym, cur_t, lo, hi):
    """Events s of the *earlier* symbol valid for a later event at cur_t:
    lo < cur_t - s <= hi  <=>  s in [cur_t - hi, cur_t - lo)."""
    wlo = jnp.searchsorted(t_prevsym, cur_t - hi, side="left")
    whi = jnp.searchsorted(t_prevsym, cur_t - lo, side="left")
    return wlo.astype(jnp.int32), whi.astype(jnp.int32)


def _window_bounds_forward(t_nextsym, cur_t, lo, hi):
    """Events t of the *later* symbol valid after cur_t:
    lo < t - cur_t <= hi  <=>  t in (cur_t + lo, cur_t + hi]."""
    wlo = jnp.searchsorted(t_nextsym, cur_t + lo, side="right")
    whi = jnp.searchsorted(t_nextsym, cur_t + hi, side="right")
    return wlo.astype(jnp.int32), whi.astype(jnp.int32)


def track_faithful(
    times_by_sym: jax.Array,
    t_low: jax.Array,
    t_high: jax.Array,
    *,
    cap_occ: int,
    max_window: int,
    method: str = "count_scan_write",
    direction: str = "backward",
) -> Occurrences:
    """Paper-faithful parallel local tracking.

    Args:
      cap_occ: static capacity of the per-level occurrence list (the paper's
        "preallocated array"); overflow is flagged, not silently wrong.
      max_window: static bound on next-events found per thread (the paper's
        per-thread scan stops past t_high; here it is a BlockSpec-style tile).
      method: 'count_scan_write' (paper's preferred, §IV-E), 'flags'
        (CudppCompact analogue), also used by the forward/sort pipeline.
      direction: 'backward' (auto end-sorted output — paper's trick) or
        'forward' (requires the caller to sort; AtomicCompact profile).
    """
    n = times_by_sym.shape[0]
    cap = times_by_sym.shape[1]
    if direction == "backward":
        cur_t = times_by_sym[n - 1]
        carried = cur_t  # end time of the chain
        level_iter = range(n - 2, -1, -1)
    else:
        cur_t = times_by_sym[0]
        carried = cur_t  # start time of the chain
        level_iter = range(1, n)

    # widen to cap_occ
    pad = cap_occ - cap
    if pad < 0:
        raise ValueError("cap_occ must be >= per-type capacity")
    cur_t = jnp.concatenate([cur_t, jnp.full((pad,), jnp.inf, cur_t.dtype)])
    carried = jnp.concatenate([carried, jnp.full((pad,), jnp.inf, carried.dtype)])

    n_superset = jnp.sum(jnp.isfinite(cur_t)).astype(jnp.int32)
    overflow = jnp.bool_(False)

    for i in level_iter:
        if direction == "backward":
            t_sym = times_by_sym[i]
            wlo, whi = _window_bounds_backward(t_sym, cur_t, t_low[i], t_high[i])
        else:
            t_sym = times_by_sym[i]
            wlo, whi = _window_bounds_forward(t_sym, cur_t, t_low[i - 1], t_high[i - 1])
        counts = jnp.clip(whi - wlo, 0, max_window)
        overflow = overflow | jnp.any((whi - wlo) > max_window)
        cur_t, carried, n_out, ovf = compaction.compact(
            t_sym, wlo, counts, carried, cap_occ=cap_occ,
            max_window=max_window, method=method)
        overflow = overflow | ovf
        n_superset = n_superset + n_out

    if direction == "backward":
        starts, ends = cur_t, carried
    else:
        starts, ends = carried, cur_t
    valid = jnp.isfinite(starts) & jnp.isfinite(ends)
    return Occurrences(
        starts=jnp.where(valid, starts, NEG),
        ends=jnp.where(valid, ends, jnp.inf),
        valid=valid,
        n_superset=n_superset,
        overflow=overflow,
    )


def sort_by_end(occ: Occurrences) -> Occurrences:
    """End-time sort for forward-tracked occurrences (AtomicCompact's final
    sort, §IV-D: 'this procedure requires sorting')."""
    order = jnp.argsort(occ.ends)
    return Occurrences(
        starts=occ.starts[order],
        ends=occ.ends[order],
        valid=occ.valid[order],
        n_superset=occ.n_superset,
        overflow=occ.overflow,
    )


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Per-call knobs threaded from the counting API down to the engines.

    ``cap_occ``/``max_window`` size the faithful engines' static occurrence
    buffers; ``block_next``/``block_prev``/``window_tiles`` are the Pallas
    kernel's tile shape and grid-pruning bound; ``chunk`` is the fused count
    kernel's episode-rows-per-grid-step; ``interpret=None`` lets the kernel
    layer decide (interpret mode anywhere but TPU). Callers that accept
    ``None`` block knobs resolve them through ``kernels.autotune`` (per-
    (L, N, B)-bucket tuned tiles) before building this config.

    ``t_min`` restricts tracking to occurrences *seeded* at time >= t_min
    (windows only look backward, so this equals counting on the substream of
    events at/after ``t_min``). It is a traced value, not a static knob — the
    streaming miner passes a new cutoff every append without recompiling.
    The restriction is applied engine-agnostically at the dispatch layer
    (:func:`restrict_seed_row` shifts pre-cutoff events out of the
    first-symbol row), so every registered engine honors it identically.
    """

    cap_occ: Optional[int] = None
    max_window: int = 32
    block_next: int = 256
    block_prev: int = 256
    window_tiles: int = 0
    chunk: int = 8
    interpret: Optional[bool] = None
    t_min: Optional[jax.Array] = None


def restrict_seed_row(times_by_sym: jax.Array, t_min) -> jax.Array:
    """Drop first-symbol events before ``t_min`` from ``[..., N, cap]`` rows.

    The seed row is shifted left past its first index with time >= ``t_min``
    and +inf-refilled — it stays sorted, so no engine needs to know the
    restriction happened. Only the seed row is touched: earlier events of
    *later* symbols cannot appear in any occurrence seeded at/after
    ``t_min`` anyway (chains run forward in time), and leaving them in place
    keeps the transform O(cap) instead of O(N * cap).
    """
    row0 = times_by_sym[..., 0, :]
    cap = row0.shape[-1]
    t_min = jnp.asarray(t_min, jnp.float32)
    flat = row0.reshape(-1, cap)
    k = jax.vmap(
        lambda r: jnp.searchsorted(r, t_min, side="left"))(flat).astype(jnp.int32)
    idx = k[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    shifted = jnp.take_along_axis(flat, jnp.minimum(idx, cap - 1), axis=-1)
    shifted = jnp.where(idx < cap, shifted, jnp.inf).reshape(row0.shape)
    return jnp.concatenate(
        [shifted[..., None, :], times_by_sym[..., 1:, :]], axis=-2)


def consume_seed_restriction(
    times_by_sym: jax.Array, cfg: EngineConfig
) -> Tuple[jax.Array, EngineConfig]:
    """Apply ``cfg.t_min`` to the tables and strip it from the config.

    Called once at each dispatch altitude (single episode, batch, corpus)
    so engines — including future natively-batched ones — can never
    double-apply the restriction.
    """
    if cfg.t_min is None:
        return times_by_sym, cfg
    return (restrict_seed_row(times_by_sym, cfg.t_min),
            dataclasses.replace(cfg, t_min=None))


class TrackingEngine(Protocol):
    """One per-level windowed tracking strategy + compaction scheme.

    ``track`` must be jit/vmap-traceable: static shapes in, static shapes
    out, with the Occurrences padding convention (+inf ends, -inf starts).

    Engines MAY additionally provide a natively-batched

        ``track_batch(times_by_sym f32[B, N, cap], t_low f32[B, N-1],
                      t_high f32[B, N-1], cfg) -> Occurrences``

    returning batch-leading Occurrences (``starts/ends/valid`` are
    ``[B, cap]``, ``n_superset``/``overflow`` are ``[B]``). When present,
    ``counting.count_batch_indexed`` dispatches an entire candidate batch
    through it in one call instead of vmapping the per-episode ``track`` —
    the fused-kernel fast path.

    Engines MAY further provide a natively corpus-batched

        ``track_corpus(times_by_sym f32[S, B, N, cap], t_low f32[B, N-1],
                       t_high f32[B, N-1], cfg) -> Occurrences``

    with ``[S, B]``-leading outputs: one shared candidate batch tracked
    against ``S`` independent streams (the per-episode windows broadcast
    over the stream axis). ``counting.count_corpus_indexed`` dispatches
    whole corpora through it — the fused engine folds ``(stream, episode)``
    into its batch grid dimension, ONE launch per mining level for the
    whole corpus.

    Engines MAY also provide a natively-counting

        ``count_batch(times_by_sym f32[B, N, cap], t_low f32[B, N-1],
                      t_high f32[B, N-1], prev_end f32[B], prev_count i32[B],
                      cfg) -> (counts i32[B], end_out f32[B],
                               n_superset i32[B], overflow bool[B])``

    running tracking + compaction + the ``greedy_scan_state`` non-overlap
    scheduler end-to-end (carry-in/carry-out chain state, so the streaming
    stitch is engine-invariant). When present,
    ``counting.count_batch_dispatch`` routes whole count calls through it —
    ONE kernel launch per (level, candidate batch), occurrence intervals
    never leaving VMEM. Engines without it fall back to
    ``track_batch_dispatch`` + the host-side greedy fold; results are
    bit-for-bit identical either way.
    """

    name: str

    def track(
        self,
        times_by_sym: jax.Array,   # f32[N, cap] sorted rows, +inf padded
        t_low: jax.Array,          # f32[N-1]
        t_high: jax.Array,         # f32[N-1]
        cfg: EngineConfig,
    ) -> Occurrences:
        ...


_REGISTRY: Dict[str, TrackingEngine] = {}


def register_engine(engine: TrackingEngine, *,
                    overwrite: bool = False) -> TrackingEngine:
    if engine.name in _REGISTRY and not overwrite:
        raise ValueError(f"engine {engine.name!r} already registered")
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> TrackingEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"engine must be one of {engine_names()}, got {name!r}") from None


def engine_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def track_batch_dispatch(
    engine,                    # str name or TrackingEngine
    times_by_sym: jax.Array,   # f32[B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,          # f32[B, N-1]
    t_high: jax.Array,         # f32[B, N-1]
    cfg: EngineConfig,
) -> Occurrences:
    """Batch-leading tracking through any engine.

    Engines exposing the native ``track_batch`` protocol method get the
    whole batch in one call (the fused-kernel fast path); everything else is
    vmapped over its per-episode ``track``. This is the ONE place batched
    dispatch lives — ``counting.count_batch_indexed`` and the sharded
    counters in ``core/distributed.py`` both route through it, so an engine
    gains multi-device support by registering, nothing more.

    Returns batch-leading Occurrences: ``starts/ends/valid`` are
    ``[B, cap]``, ``n_superset``/``overflow`` are ``[B]``.
    """
    eng = get_engine(engine) if isinstance(engine, str) else engine
    times_by_sym, cfg = consume_seed_restriction(times_by_sym, cfg)
    track_batch = getattr(eng, "track_batch", None)
    if track_batch is not None:
        return track_batch(times_by_sym, t_low, t_high, cfg)
    return jax.vmap(lambda t, lo, hi: eng.track(t, lo, hi, cfg))(
        times_by_sym, t_low, t_high)


def track_corpus_dispatch(
    engine,                    # str name or TrackingEngine
    times_by_sym: jax.Array,   # f32[S, B, N, cap] sorted rows, +inf padded
    t_low: jax.Array,          # f32[B, N-1] shared across streams
    t_high: jax.Array,         # f32[B, N-1]
    cfg: EngineConfig,
) -> Occurrences:
    """Corpus-leading tracking through any engine.

    One candidate batch, ``S`` independent streams: engines exposing the
    native ``track_corpus`` protocol method get the whole corpus in one
    call (the fused engine folds the stream axis into its batch grid — one
    launch per level for every stream); everything else is vmapped over the
    stream axis of :func:`track_batch_dispatch`, which in turn uses the
    engine's ``track_batch`` when present. This is the ONE place corpus
    dispatch lives — the local and sharded corpus counters both route
    through it, so an engine gains multi-stream (and stream-sharded)
    support by registering, nothing more.

    Returns ``[S, B]``-leading Occurrences: ``starts/ends/valid`` are
    ``[S, B, cap]``, ``n_superset``/``overflow`` are ``[S, B]``.
    """
    eng = get_engine(engine) if isinstance(engine, str) else engine
    times_by_sym, cfg = consume_seed_restriction(times_by_sym, cfg)
    track_corpus = getattr(eng, "track_corpus", None)
    if track_corpus is not None:
        return track_corpus(times_by_sym, t_low, t_high, cfg)
    return jax.vmap(
        lambda t: track_batch_dispatch(eng, t, t_low, t_high, cfg))(
        times_by_sym)


@dataclasses.dataclass(frozen=True)
class DenseEngine:
    """Beyond-paper windowed range-max tracking (no compaction at all)."""

    name: str = "dense"

    def track(self, times_by_sym, t_low, t_high, cfg: EngineConfig) -> Occurrences:
        return track_dense(times_by_sym, t_low, t_high)


@dataclasses.dataclass(frozen=True)
class FaithfulEngine:
    """Paper Algorithm 2 tracking with a pluggable compaction strategy."""

    name: str
    method: str = "count_scan_write"
    direction: str = "backward"
    sort_output: bool = False   # AtomicCompact profile: forward + final sort

    def track(self, times_by_sym, t_low, t_high, cfg: EngineConfig) -> Occurrences:
        cap = times_by_sym.shape[1]
        # `is None`, not `or`: an explicit cap_occ=0 must be rejected by
        # track_faithful's capacity check, not silently widened to cap
        cap_occ = cap if cfg.cap_occ is None else cfg.cap_occ
        occ = track_faithful(
            times_by_sym, t_low, t_high,
            cap_occ=cap_occ, max_window=cfg.max_window,
            method=self.method, direction=self.direction)
        return sort_by_end(occ) if self.sort_output else occ


def _pallas_tile_geometry(cap: int, cfg: EngineConfig):
    """(bn, bp, padded_cap) for the Pallas engines: the engine-policy block
    clamp ([8, 256] — VMEM-friendly defaults) composed with the single
    shared tiling rule in kernels/ops.py, so the per-level and fused
    engines tile identically (their conservative window-truncation checks
    must agree tile-for-tile)."""
    from ..kernels import ops  # deferred: core stays importable sans pallas

    return ops.tile_geometry(
        cap, max(8, min(cfg.block_next, 256)), max(8, min(cfg.block_prev, 256)))


@dataclasses.dataclass(frozen=True)
class DensePallasEngine:
    """Dense tracking with each level executed by the Pallas TPU kernel.

    Same dominance argument (and therefore the same counts) as ``dense``,
    but the windowed range-max runs as tiled broadcast-compare + row-max in
    VMEM (kernels/episode_track.py). The level arrays are padded up to a
    common multiple of the tile sizes — max-accumulation over +inf/-inf
    padding is a no-op, so this is harmless — and sliced back afterwards.

    ``window_tiles > 0`` caps how many prev tiles each next tile scans; a
    too-small cap would truncate constraint windows, so any level where a
    next tile's window may not fit is reported through ``overflow`` (the
    same convention as the faithful engines' capacity misses — flagged,
    never silently wrong). ``window_tiles=0`` is always exact.
    """

    name: str = "dense_pallas"

    def track(self, times_by_sym, t_low, t_high, cfg: EngineConfig) -> Occurrences:
        from ..kernels import ops  # deferred: core stays importable sans pallas

        n, cap = times_by_sym.shape
        bn, bp, pcap = _pallas_tile_geometry(cap, cfg)

        def pad_t(row):
            return jnp.concatenate(
                [row, jnp.full((pcap - cap,), jnp.inf, row.dtype)])

        t0 = times_by_sym[0]
        v = jnp.where(jnp.isfinite(t0), t0, NEG)
        n_superset = jnp.sum(jnp.isfinite(t0)).astype(jnp.int32)
        overflow = jnp.bool_(False)
        v = jnp.concatenate([v, jnp.full((pcap - cap,), NEG, v.dtype)])
        t_prev = pad_t(t0)
        for i in range(n - 1):
            t_next = pad_t(times_by_sym[i + 1])
            if cfg.window_tiles > 0 and cfg.window_tiles < pcap // bp:
                # same shared predicate as the fused engine's precompute
                overflow = overflow | ops.window_truncated(
                    t_prev, t_next, t_high[i], bn, bp, cfg.window_tiles)
            v = ops.track_level(
                t_prev, v, t_next, t_low[i], t_high[i],
                block_next=bn, block_prev=bp,
                window_tiles=cfg.window_tiles, interpret=cfg.interpret)
            v = jnp.where(jnp.isfinite(t_next), v, NEG)
            n_superset = n_superset + jnp.sum(v > NEG).astype(jnp.int32)
            t_prev = t_next
        v = v[:cap]
        ends = times_by_sym[n - 1]
        valid = (v > NEG) & jnp.isfinite(ends)
        return Occurrences(
            starts=v,
            ends=jnp.where(valid, ends, jnp.inf),
            valid=valid,
            n_superset=n_superset,
            overflow=overflow,
        )


@dataclasses.dataclass(frozen=True)
class FusedDensePallasEngine:
    """Dense tracking for a whole candidate batch in ONE fused Pallas launch.

    Same dominance argument (and counts) as ``dense``/``dense_pallas``, but
    instead of ``B x (N-1)`` per-level kernel launches with HBM round-trips
    between them, the whole batch runs on a ``(episodes, levels, next_tiles)``
    grid: latest-start values stay in VMEM scratch across levels, the
    per-(episode, level, tile) scan offsets are scalar-prefetched as one
    precomputed table, and each next tile walks exactly the prev tiles its
    constraint window spans (a dynamic in-kernel loop — no static quadratic
    tile coverage). See kernels/episode_track.py and DESIGN.md §2.

    ``track_batch`` is the native entry point (dispatched by
    ``counting.count_batch_indexed``); ``track`` wraps it with a singleton
    batch so the engine also serves the per-episode API. ``window_tiles``
    keeps the per-level engine's semantics: 0 = exact, > 0 caps each tile's
    scan length and flags possible truncation through ``overflow`` using
    the same conservative span bound as ``dense_pallas``.
    """

    name: str = "dense_pallas_fused"

    def track(self, times_by_sym, t_low, t_high, cfg: EngineConfig) -> Occurrences:
        occ = self.track_batch(
            times_by_sym[None], t_low[None], t_high[None], cfg)
        return Occurrences(*(x[0] for x in occ))

    def track_batch(self, times_by_sym, t_low, t_high,
                    cfg: EngineConfig) -> Occurrences:
        from ..kernels import ops  # deferred: core stays importable sans pallas

        # same policy-clamped blocks as the per-level engine; ops.track_batch
        # applies the shared tile_geometry rule, so the two Pallas engines'
        # conservative truncation checks agree tile-for-tile
        bn, bp, _ = _pallas_tile_geometry(times_by_sym.shape[-1], cfg)
        starts, n_superset, truncated = ops.track_batch(
            times_by_sym, t_low, t_high, block_next=bn, block_prev=bp,
            window_tiles=cfg.window_tiles, interpret=cfg.interpret)
        ends = times_by_sym[:, -1, :]
        valid = (starts > NEG) & jnp.isfinite(ends)
        return Occurrences(
            starts=starts,
            ends=jnp.where(valid, ends, jnp.inf),
            valid=valid,
            n_superset=n_superset,
            overflow=truncated,
        )

    def count_batch(self, times_by_sym, t_low, t_high, prev_end, prev_count,
                    cfg: EngineConfig):
        """Single-launch count pipeline: tracking + in-VMEM count_scan_write
        compaction + the greedy_scan_state fold, one kernel for the whole
        batch (kernels/episode_track.py::count_batch_pallas, DESIGN.md §10).

        Returns ``(counts i32[B], end_out f32[B], n_superset i32[B],
        overflow bool[B])`` with the carried chain state included, exactly
        as the track + host-greedy path would produce.
        """
        from ..kernels import ops  # deferred: core stays importable sans pallas

        bn, bp, _ = _pallas_tile_geometry(times_by_sym.shape[-1], cfg)
        return ops.count_batch(
            times_by_sym, t_low, t_high, prev_end, prev_count,
            block_next=bn, block_prev=bp, window_tiles=cfg.window_tiles,
            chunk=cfg.chunk, interpret=cfg.interpret)

    def track_corpus(self, times_by_sym, t_low, t_high,
                     cfg: EngineConfig) -> Occurrences:
        from ..kernels import ops  # deferred: core stays importable sans pallas

        # stream axis folded into the batch grid dimension (ops.track_corpus):
        # per-row tracking is identical to track_batch row-for-row, so the
        # corpus path inherits the fused engine's exactness bit-for-bit
        bn, bp, _ = _pallas_tile_geometry(times_by_sym.shape[-1], cfg)
        starts, n_superset, truncated = ops.track_corpus(
            times_by_sym, t_low, t_high, block_next=bn, block_prev=bp,
            window_tiles=cfg.window_tiles, interpret=cfg.interpret)
        ends = times_by_sym[:, :, -1, :]
        valid = (starts > NEG) & jnp.isfinite(ends)
        return Occurrences(
            starts=starts,
            ends=jnp.where(valid, ends, jnp.inf),
            valid=valid,
            n_superset=n_superset,
            overflow=truncated,
        )


register_engine(DenseEngine())
register_engine(FaithfulEngine("count_scan_write", direction="backward"))
register_engine(FaithfulEngine("atomic_sort", direction="forward", sort_output=True))
register_engine(FaithfulEngine("flags", method="flags", direction="backward"))
register_engine(DensePallasEngine())
register_engine(FusedDensePallasEngine())
