"""MapConcat baseline (paper §III-B, Figs 4–5) — the prior GPU approach.

The input stream is cut into S segments; each segment runs state machines in
parallel (the Map step) and per-segment results are stitched (the Concat
step). The CUDA original enumerates all possible FSM entry states by
starting machines at multiple offsets into the previous segment; on TPU the
idiomatic equivalent (DESIGN.md §2) is:

  * Map: one ring-buffer FSM (`statemachine.count_fsm_scan`) per segment,
    vmapped; each segment is extended by a halo of events from the next
    segment so occurrences *starting* in the segment can complete across the
    boundary (paper Fig 4: "continues over into the next segment to complete
    the last occurrence"). Occurrence (start,end) intervals are recorded.
  * Concat: greedy interval scheduling over the concatenated, end-sorted
    per-segment interval lists (paper Fig 5's merge, generalized).

Exactness: unlike the CUDA original (whose multi-offset merge the paper
shows to be fragile), our Map step records the *dominance superset* of
occurrence intervals per segment (latest start per completing end event,
without clearing), so the global greedy Concat is exact by construction
whenever the static capacities hold: ring size covers live same-symbol
events, occ_per_segment covers per-segment completions (overflow is
flagged), and the halo (one full segment of events) spans episode.max_span.
The *cost profile* is the point of the baseline: a sequential scan over
events inside each segment, parallel only across segments.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from .episodes import Episode
from .events import EventStream
from .statemachine import NEG
from .tracking import Occurrences
from . import scheduling


def _segment_with_halo(types, times, n_segments: int, halo: int):
    """[n] -> [S, seg+halo] with +inf padded tails; events beyond segment
    boundaries are masked for *seeding* via seg_start_time."""
    n = types.shape[0]
    seg = -(-n // n_segments)  # ceil
    padded_n = seg * n_segments + halo
    pt = jnp.full((padded_n,), jnp.inf, times.dtype).at[:n].set(times)
    py = jnp.full((padded_n,), -1, types.dtype).at[:n].set(types)
    idx = (jnp.arange(n_segments)[:, None] * seg
           + jnp.arange(seg + halo)[None, :])
    return py[idx], pt[idx], seg


def count_mapconcat(
    stream: EventStream,
    episode: Episode,
    *,
    n_segments: int = 8,
    ring: int = 8,
    occ_per_segment: int = 64,
) -> jax.Array:
    """Non-overlapped count via the MapConcat strategy."""
    types = jnp.asarray(stream.types, jnp.int32)
    times = jnp.asarray(stream.times, jnp.float32)
    n = types.shape[0]
    # halo: enough events to cover max_span past the boundary; conservative
    # static bound = all events (cap by segment length)
    seg = -(-n // n_segments)
    halo = min(n, seg)
    seg_types, seg_times, seg_len = _segment_with_halo(types, times, n_segments, halo)
    # boundary time of each segment: occurrences must START inside the segment
    seg_start_idx = jnp.arange(n_segments) * seg_len
    seg_end_time = jnp.where(
        (seg_start_idx + seg_len - 1) < n,
        times[jnp.clip(seg_start_idx + seg_len - 1, 0, n - 1)],
        jnp.inf,
    )

    nsym = episode.n
    sym, lo, hi = episode.as_arrays()

    def map_step(seg_ty, seg_tm, t_hi):
        """FSM over one segment (with halo); records occurrence intervals
        whose start time is <= segment end boundary."""
        ring_bufs = jnp.full((nsym, ring), NEG, jnp.float32)
        ring_start = jnp.full((nsym, ring), NEG, jnp.float32)  # chain start times
        heads = jnp.zeros((nsym,), jnp.int32)
        occ_s = jnp.full((occ_per_segment,), NEG, jnp.float32)
        occ_e = jnp.full((occ_per_segment,), jnp.inf, jnp.float32)
        n_occ = jnp.int32(0)

        def step(carry, ev):
            bufs, bstarts, hds, os_, oe_, cnt = carry
            e, t = ev
            valid = jnp.isfinite(t)

            def match_prev(j):
                ok = (bufs[j - 1] > NEG) & (t - bufs[j - 1] > lo[j - 1]) & (
                    t - bufs[j - 1] <= hi[j - 1])
                any_ok = jnp.any(ok)
                # latest start among matching predecessors (dominance)
                st = jnp.max(jnp.where(ok, bstarts[j - 1], NEG))
                return any_ok, st

            if nsym == 1:
                completes = valid & (e == sym[0]) & (t <= t_hi)
                comp_start = t
            else:
                any_ok, st = match_prev(nsym - 1)
                completes = valid & (e == sym[nsym - 1]) & any_ok
                comp_start = st

            new_bufs, new_bstarts, new_hds = bufs, bstarts, hds
            for j in range(nsym - 1):
                if j == 0:
                    add = valid & (e == sym[0]) & (t <= t_hi)
                    st_j = t
                else:
                    ok_j, st_j = match_prev(j)
                    add = valid & (e == sym[j]) & ok_j
                # NB: no `~completes` mask — without clearing, a completing
                # event must still be buffered at earlier positions it
                # matches (e.g. the last A of A->A->A seeds the next chain)
                new_bufs = jnp.where(add, new_bufs.at[j, new_hds[j]].set(t), new_bufs)
                new_bstarts = jnp.where(
                    add, new_bstarts.at[j, new_hds[j]].set(st_j), new_bstarts)
                new_hds = jnp.where(
                    add, new_hds.at[j].set((new_hds[j] + 1) % ring), new_hds)

            # record the completed occurrence interval; do NOT clear state —
            # overlap resolution is global (Concat step), mirroring the
            # speculative multi-machine Map of the paper. Entries past the
            # static capacity are dropped (overflow flagged below).
            slot = jnp.where(cnt < occ_per_segment, cnt, occ_per_segment)
            os_ = jnp.where(completes, os_.at[slot].set(comp_start, mode="drop"), os_)
            oe_ = jnp.where(completes, oe_.at[slot].set(t, mode="drop"), oe_)
            cnt = cnt + completes.astype(jnp.int32)
            return (new_bufs, new_bstarts, new_hds, os_, oe_, cnt), None

        carry0 = (ring_bufs, ring_start, heads, occ_s, occ_e, n_occ)
        (_, _, _, os_, oe_, cnt), _ = lax.scan(step, carry0, (seg_ty, seg_tm))
        return os_, oe_, cnt

    occ_s, occ_e, seg_counts = jax.vmap(map_step)(seg_types, seg_times, seg_end_time)

    # Concat: global greedy over all recorded intervals, sorted by end time
    flat_s, flat_e = occ_s.reshape(-1), occ_e.reshape(-1)
    order = jnp.argsort(flat_e)
    flat_s, flat_e = flat_s[order], flat_e[order]
    valid = jnp.isfinite(flat_e) & (flat_s > NEG)
    occ = Occurrences(starts=flat_s, ends=flat_e, valid=valid,
                      n_superset=jnp.sum(seg_counts),
                      overflow=jnp.any(seg_counts > occ_per_segment))
    return scheduling.greedy_scan(occ)
