"""Streaming miner: absorb appended spike chunks incrementally (DESIGN.md §9).

The paper's pitch is closing the latency gap between recording and analysis;
its companion work (*Towards Chip-on-Chip Neuroscience*) makes the loop
explicit — spikes arrive continuously and the mining result should track
them. Every batch entry point in this repo (``mine``, ``mine_arrays``,
``mine_sharded``, ``mine_corpus``) remines the full stream from scratch;
:class:`StreamingMiner` instead keeps the whole mining state device-resident
between calls and makes ``append(types, times)`` cost work proportional to
the *chunk*, not the stream:

* **Incremental index** — the per-type time table (the paper's §IV-A
  pre-process) persists across appends; each chunk is scattered into it at
  per-type offsets (:func:`events.type_index_update`), with geometric
  capacity growth (:func:`events.grow_type_index`) so reallocation — and
  the recompile a new static width implies — happens O(log n) times over a
  stream's life. The index *is* the device append buffer: each row is that
  type's events in arrival order.

* **Tail-delta recount** — an occurrence ending at a chunk event reaches at
  most ``span = sum(t_high)`` back in time, so only the span-bounded stream
  suffix can seed new occurrences. Tracking runs on a narrow suffix view
  whose final-symbol row holds *only* the chunk's events
  (:func:`counting.count_tail_batch_indexed`, threading the ``t_min``
  cutoff through the engine config), and the resulting intervals — all
  ending at/after every cached interval's end — are folded onto each
  episode's cached greedy chain state (:func:`scheduling.greedy_state`).
  This is the same stitch the sharded miner performs at shard boundaries
  (core/distributed.py), with the boundary at the old stream end.

* **Warm frontier, scoped backfill** — non-overlapped counts are monotone
  under appends (old occurrence intervals never change; chunks only add
  intervals), so frequent episodes stay frequent and their cached chain
  states stay warm. A candidate first reached when a sub-episode *becomes*
  frequent has no cached state; exactly those rows are backfilled once over
  the whole indexed history (:func:`counting.count_batch_indexed_stateful`)
  and kept warm from then on. Both paths for a level are dispatched before
  a single ``device_get`` — one host sync per level per append, the same
  budget as the batch miners.

``append`` returns the full-stream per-level result, bit-for-bit what
``mine_arrays`` returns for the concatenated stream (differentially tested
across engines and chunkings, including duplicate boundary timestamps and
all-padding chunks) — equivalence holds whenever the cold run itself does
not overflow its static capacities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import counting
from . import events as events_lib
from . import plan as plan_mod
from .events import EventStream
from .mining import (_OVERFLOW_MSG, LevelArrays, MinerConfig, _prune_level,
                     generate_candidates_arrays, pad_candidate_rows)

_TAIL_SHORT_MSG = (
    "streaming tail view narrower than a symbol's span-bounded suffix; "
    "this is a StreamingMiner sizing bug (host and device suffix bounds "
    "disagree) — please report")


@dataclasses.dataclass
class _ChainState:
    """Cached greedy chain state of one episode through append ``seq``.

    Shared with :mod:`serving` — a pooled session's per-episode carry is
    exactly a solo miner's (that identity is the serving differential bar).
    """

    prev_end: float   # end time of the last interval the greedy took
    count: int        # non-overlapped count over the whole stream so far
    seq: int          # last append this state was advanced through


def clean_chunk(types, times, n_types: int, last_time: float):
    """Validate one appended chunk and strip its padding (host-side).

    THE chunk acceptance rule, shared by :class:`StreamingMiner` and the
    serving session pool: ``types < 0`` / non-finite times are padding and
    are dropped (fixed-size device feeds hand buffers over as-is); what
    remains must be in-alphabet, time-sorted, and start at/after
    ``last_time``. Returns filtered ``(types i32[m], times f32[m])`` —
    possibly empty — or raises ``ValueError``.
    """
    types = np.asarray(types, np.int32).reshape(-1)
    times = np.asarray(times, np.float32).reshape(-1)
    if types.shape != times.shape:
        raise ValueError("types/times length mismatch")
    keep = (types >= 0) & np.isfinite(times)
    types, times = types[keep], times[keep]
    if types.size == 0:
        return types, times
    if np.any(types >= n_types):
        raise ValueError("event types out of range")
    if np.any(np.diff(times) < 0) or times[0] < last_time:
        raise ValueError("appended chunk must be time-sorted and start "
                         "at/after the last appended event")
    return types, times


def suffix_cutoff(cfg: MinerConfig, chunk_start: float, chunk_end: float):
    """Span-bounded suffix cutoff ``t0`` for a chunk starting at
    ``chunk_start``: occurrences ending at chunk events start at/after
    ``chunk_start - span``. The engines compare gaps in f32
    (``t_prev >= t_next - hi``), so each of the up-to-``(max_level - 1)``
    hops can admit ~an ulp of absolute error at the magnitude of the
    times / t_high involved — the slack must be ABSOLUTE at that scale,
    not relative at t0's (t0 can sit near zero while the stream lives at
    large magnitudes). Extra history in the view is provably harmless; a
    missing seed would not be. Shared by :class:`StreamingMiner` and the
    serving session pool — bit-identical cutoffs are part of the serving
    differential bar.
    """
    span = (cfg.max_level - 1) * float(cfg.t_high)
    scale = max(abs(float(chunk_start)), abs(float(chunk_end)), span)
    slack = 8.0 * cfg.max_level * float(np.spacing(np.float32(scale)))
    t0 = np.float32(np.float64(chunk_start) - span - slack)
    return np.nextafter(t0, np.float32(-np.inf), dtype=np.float32)


class StreamingMiner:
    """Device-resident incremental level-wise miner (one stream, appends).

    Args:
      n_types: event-type alphabet size (fixed for the stream's life).
      cfg: the usual :class:`MinerConfig`. ``cfg.cap`` seeds the initial
        per-type capacity (it *grows* geometrically as events arrive, so it
        is a hint, not a limit); ``cfg.mesh`` is rejected — the streaming
        state machine is single-device.
      initial_cap: overrides the initial per-type capacity (default:
        ``cfg.cap``, else 256).
      growth: capacity growth factor (> 1) for the per-type index.

    ``append(types, times) -> Dict[int, LevelArrays]`` absorbs one
    time-sorted chunk (``types < 0`` / non-finite times are padding and are
    dropped, so fixed-size device feeds can hand their buffers over as-is)
    and returns the per-level frequent episodes of the whole stream so far.
    """

    def __init__(self, n_types: int, cfg: MinerConfig, *,
                 initial_cap: Optional[int] = None, growth: float = 2.0):
        if cfg.mesh is not None:
            raise ValueError("StreamingMiner is single-device; cfg.mesh must "
                             "be None (shard whole streams, not the tail)")
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if n_types < 1:
            raise ValueError(f"n_types must be >= 1, got {n_types}")
        self.n_types = int(n_types)
        self.cfg = cfg
        self.growth = float(growth)
        if initial_cap is None:
            initial_cap = 256 if cfg.cap is None else cfg.cap
        # snap to a capacity class (pow2): the index width is part of the
        # MiningPlan bucket, so class-aligned widths make every counting
        # dispatch land on an exact cached executable (plan.py) — the cap
        # is a growth hint, never a limit, so rounding up is free
        self.cap = plan_mod.capacity_class(max(1, initial_cap))
        self.table = jnp.full((self.n_types, self.cap), jnp.inf, jnp.float32)
        self.counts_dev = jnp.zeros((self.n_types,), jnp.int32)
        self.counts = np.zeros((self.n_types,), np.int64)  # exact host mirror
        self.n_events = 0
        self.last_time = -np.inf
        self.seq = 0              # appends absorbed (empty chunks excluded)
        # host copies of the accepted events (amortized-growth buffers, so
        # appends stay O(chunk), not O(stream)): they size the tail view
        # exactly and let tests/demos rebuild the cold reference stream
        self._buf_types = np.empty((1024,), np.int32)
        self._buf_times = np.empty((1024,), np.float32)
        self._cache: Dict[int, Dict[tuple, _ChainState]] = {}
        self._results: Optional[Dict[int, LevelArrays]] = None

    # -- public surface ----------------------------------------------------

    @property
    def _all_types(self) -> np.ndarray:
        return self._buf_types[:self.n_events]

    @property
    def _all_times(self) -> np.ndarray:
        return self._buf_times[:self.n_events]

    def stream(self) -> EventStream:
        """The accepted events so far, as a host-side EventStream."""
        return EventStream(self._all_types.copy(), self._all_times.copy(),
                           self.n_types)

    def plans(self, *, batches=None, tail_caps=()):
        """MiningPlans this miner will dispatch at its current capacity.

        Feed the result to :func:`plan.warm` at serving startup so the
        first live append pays zero compiles (DESIGN.md §11). ``tail_caps``
        are the expected tail-view widths (capacity classes, floor 16 —
        a feed's chunk size + event rate x span bounds them); the
        cold-backfill and plain-indexed plans are always included.
        """
        return plan_mod.plans_for_miner(
            dataclasses.replace(self.cfg, cap=self.cap),  # the LIVE width,
            n_types=self.n_types, n_events=self.cap,      # not the cfg hint
            batches=batches, streaming=True,
            tail_caps=[plan_mod.capacity_class(int(t), floor=16)
                       for t in tail_caps])

    @property
    def results(self) -> Dict[int, LevelArrays]:
        """Per-level result of the last append (computed if never mined)."""
        if self._results is None:
            self._results = self._mine_levels(t_tail_start=None, tail_cap=0,
                                              old_counts_dev=self.counts_dev)
        return dict(self._results)

    def append(self, types, times) -> Dict[int, LevelArrays]:
        types, times = clean_chunk(types, times, self.n_types, self.last_time)
        if types.size == 0:
            return self.results         # nothing can change (already a copy)

        # 1) incremental index: grow-if-needed, then scatter ONLY the chunk
        old_counts_dev = self.counts_dev
        self.counts = self.counts + np.bincount(types, minlength=self.n_types)
        needed = int(self.counts.max())
        if needed > self.cap:
            new_cap = self.cap
            while new_cap < needed:
                new_cap = max(new_cap + 1, int(new_cap * self.growth))
            # class-align the grown width (rounds up, so still >= needed)
            new_cap = plan_mod.capacity_class(new_cap)
            self.table = events_lib.grow_type_index(self.table, new_cap)
            self.cap = new_cap
        self.table, self.counts_dev = events_lib.type_index_update(
            self.table, self.counts_dev, types, times)
        if self.n_events + types.size > self._buf_times.size:
            new_size = max(self.n_events + int(types.size),
                           2 * self._buf_times.size)
            self._buf_types = np.concatenate(
                [self._all_types, np.empty((new_size - self.n_events,),
                                           np.int32)])
            self._buf_times = np.concatenate(
                [self._all_times, np.empty((new_size - self.n_events,),
                                           np.float32)])
        self._buf_types[self.n_events:self.n_events + types.size] = types
        self._buf_times[self.n_events:self.n_events + types.size] = times
        self.n_events += int(types.size)
        self.last_time = float(times[-1])
        self.seq += 1

        # 2) span-bounded suffix cutoff (see suffix_cutoff for the f32
        # slack rationale: absolute at stream magnitude, never relative)
        t0 = suffix_cutoff(self.cfg, float(times[0]), float(times[-1]))
        # exact host sizing of the widest per-type suffix
        i0 = int(np.searchsorted(self._all_times, t0, side="left"))
        suffix = np.bincount(self._all_types[i0:], minlength=self.n_types)
        # capacity-class sizing (floor 16): the tail view's width is part
        # of the MiningPlan bucket, so steady-state feeds land on O(log)
        # distinct tail plans — each compiled once, ever (plan.py)
        tail_cap = plan_mod.capacity_class(int(suffix.max()), floor=16)

        self._results = self._mine_levels(
            t_tail_start=t0, tail_cap=tail_cap, old_counts_dev=old_counts_dev)
        # evict chain states not advanced through THIS append: warmth next
        # append requires seq == self.seq, so anything older can only ever
        # be re-counted cold — keeping it would grow the cache with every
        # candidate ever seen instead of the live candidate set
        for cache in self._cache.values():
            stale = [k for k, st in cache.items() if st.seq != self.seq]
            for k in stale:
                del cache[k]
        return dict(self._results)   # a copy: mutating it must not corrupt
                                     # the cached results the next (empty)
                                     # append or `.results` read returns

    # -- level loop (mirrors mining._mine_levels' control flow exactly) ----

    def _mine_levels(self, *, t_tail_start, tail_cap, old_counts_dev):
        cfg = self.cfg
        binc = self.counts
        freq_types = np.nonzero(binc >= cfg.threshold)[0].astype(np.int32)
        results = {1: _prune_level(freq_types, binc, self.n_types)}
        frontier = results[1].symbols
        for level in range(2, cfg.max_level + 1):
            if frontier.shape[0] == 0:
                break
            cands = generate_candidates_arrays(frontier, level, cfg)
            b = cands.shape[0]
            if b == 0:
                results[level] = LevelArrays(
                    np.zeros((0, level), np.int32), np.zeros((0,), np.int32), 0)
                break
            thr = (cfg.level_thresholds or {}).get(level, cfg.threshold)
            counts_h = self._count_candidates(
                level, cands, t_tail_start, tail_cap, old_counts_dev)
            keep = counts_h >= thr
            frontier = cands[keep]
            results[level] = LevelArrays(
                frontier, counts_h[keep].astype(np.int32), b)
        return results

    def _count_candidates(self, level, cands, t_tail_start, tail_cap,
                          old_counts_dev) -> np.ndarray:
        """Count one level's candidate rows: warm tail-delta + cold backfill.

        Warm = a chain state advanced through the previous append exists
        (frequent episodes — and still-infrequent candidates — are recounted
        every append, so they stay warm for as long as they stay joined).
        Everything else is backfilled over the whole indexed history. Both
        dispatches are fetched in ONE ``device_get``.
        """
        cfg = self.cfg
        cache = self._cache.setdefault(level, {})
        keys = [tuple(int(x) for x in row) for row in cands]
        warm_idx, cold_idx = [], []
        for i, key in enumerate(keys):
            st = cache.get(key)
            if (t_tail_start is not None and st is not None
                    and st.seq == self.seq - 1):
                warm_idx.append(i)
            else:
                cold_idx.append(i)

        # None block knobs flow to the counting entries, which resolve them
        # through kernels.autotune — warm tail recounts and cold backfills
        # inherit per-bucket tuned tiles without any streaming-layer config
        knobs = dict(
            engine=cfg.engine, cap_occ=cfg.cap_occ, max_window=cfg.max_window,
            parallel_schedule=cfg.parallel_schedule, block_next=cfg.block_next,
            block_prev=cfg.block_prev, window_tiles=cfg.window_tiles,
            interpret=cfg.interpret)
        dispatched = []
        if warm_idx:
            sym, lo, hi = pad_candidate_rows(cands[np.asarray(warm_idx)],
                                             level, cfg)
            bp = int(sym.shape[0])
            pe = np.full((bp,), -np.inf, np.float32)
            pc = np.zeros((bp,), np.int32)
            for j, i in enumerate(warm_idx):
                st = cache[keys[i]]
                pe[j], pc[j] = st.prev_end, st.count
            # padding rows repeat episode 0 — give them its carry too (their
            # results are computed and discarded, same as the batch miners)
            pe[len(warm_idx):] = pe[0]
            pc[len(warm_idx):] = pc[0]
            dispatched.append(("warm", warm_idx, counting.count_tail_batch_indexed(
                self.table, self.counts_dev, old_counts_dev,
                np.float32(t_tail_start), sym, lo, hi,
                jnp.asarray(pe), jnp.asarray(pc), tail_cap=tail_cap, **knobs)))
        if cold_idx:
            sym, lo, hi = pad_candidate_rows(cands[np.asarray(cold_idx)],
                                             level, cfg)
            bp = int(sym.shape[0])
            dispatched.append(("cold", cold_idx, counting.count_batch_indexed_stateful(
                self.table, self.counts_dev, sym, lo, hi,
                jnp.full((bp,), -jnp.inf, jnp.float32),
                jnp.zeros((bp,), jnp.int32), **knobs)))

        counts_out = np.zeros((len(keys),), np.int64)
        fetched = jax.device_get([d[2] for d in dispatched])  # ONE sync
        for (kind, idxs, _), vals in zip(dispatched, fetched):
            m = len(idxs)
            if kind == "warm":
                cnt, pend, _nsup, overflow, tail_short = vals
                if bool(np.any(tail_short[:m])):
                    raise RuntimeError(_TAIL_SHORT_MSG)
            else:
                cnt, pend, _nsup, overflow = vals
            if bool(np.any(overflow[:m])):
                raise RuntimeError(_OVERFLOW_MSG)
            for j, i in enumerate(idxs):
                counts_out[i] = int(cnt[j])
                cache[keys[i]] = _ChainState(
                    prev_end=float(pend[j]), count=int(cnt[j]), seq=self.seq)
        return counts_out
