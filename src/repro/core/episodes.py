"""Serial episodes with inter-event time constraints (paper §II-C, Def. 2).

An N-node serial episode ``A -(l1,h1]-> B -(l2,h2]-> C ...`` pairs N event
types with N-1 half-open inter-event windows: a valid occurrence satisfies
``l_i < t_{i+1} - t_i <= h_i`` for every consecutive pair.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Episode:
    symbols: Tuple[int, ...]           # event-type ids, length N >= 1
    t_low: Tuple[float, ...] = ()      # length N-1, each >= 0
    t_high: Tuple[float, ...] = ()     # length N-1, each > t_low

    def __post_init__(self):
        object.__setattr__(self, "symbols", tuple(int(s) for s in self.symbols))
        object.__setattr__(self, "t_low", tuple(float(x) for x in self.t_low))
        object.__setattr__(self, "t_high", tuple(float(x) for x in self.t_high))
        n = len(self.symbols)
        if n < 1:
            raise ValueError("episode needs >= 1 symbol")
        if len(self.t_low) != n - 1 or len(self.t_high) != n - 1:
            raise ValueError("need N-1 inter-event constraints")
        for lo, hi in zip(self.t_low, self.t_high):
            if lo < 0:
                raise ValueError("t_low must be >= 0 (windows are (low, high])")
            if hi <= lo:
                raise ValueError("t_high must exceed t_low")

    @property
    def n(self) -> int:
        return len(self.symbols)

    @property
    def max_span(self) -> float:
        """Upper bound on (end - start) of any occurrence; halo/segment bound."""
        return float(sum(self.t_high))

    def subepisode(self, start: int, stop: int) -> "Episode":
        return Episode(
            self.symbols[start:stop],
            self.t_low[start : stop - 1],
            self.t_high[start : stop - 1],
        )

    def as_arrays(self):
        return (
            jnp.asarray(self.symbols, jnp.int32),
            jnp.asarray(self.t_low, jnp.float32),
            jnp.asarray(self.t_high, jnp.float32),
        )

    def __str__(self):
        parts = [str(self.symbols[0])]
        for s, lo, hi in zip(self.symbols[1:], self.t_low, self.t_high):
            parts.append(f"-({lo:g},{hi:g}]->{s}")
        return "".join(parts)


def serial(symbols: Sequence[int], low: float, high: float) -> Episode:
    """Episode with one shared (low, high] window for every gap."""
    n = len(symbols)
    return Episode(tuple(symbols), (low,) * (n - 1), (high,) * (n - 1))


def episodes_from_rows(
    rows, t_low: float, t_high: float
) -> "list[Episode]":
    """Inverse of :func:`episode_batch` for uniform windows.

    ``rows`` is i32[B, N] symbol rows (the miner's array form); every gap
    gets the shared (t_low, t_high] window. N == 1 rows get no windows.
    """
    rows = np.asarray(rows, np.int64)
    if rows.ndim != 2:
        raise ValueError("rows must be [B, N]")
    n = rows.shape[1]
    lo, hi = (t_low,) * (n - 1), (t_high,) * (n - 1)
    return [Episode(tuple(int(s) for s in row), lo, hi) for row in rows]


def episode_batch(episodes: Sequence[Episode]):
    """Pack same-length episodes into dense arrays for vmap counting.

    Returns (symbols [B,N] i32, t_low [B,N-1] f32, t_high [B,N-1] f32).
    """
    ns = {e.n for e in episodes}
    if len(ns) != 1:
        raise ValueError("episode_batch requires equal-length episodes")
    sym = np.asarray([e.symbols for e in episodes], np.int32)
    lo = np.asarray([e.t_low for e in episodes], np.float32).reshape(len(episodes), -1)
    hi = np.asarray([e.t_high for e in episodes], np.float32).reshape(len(episodes), -1)
    return jnp.asarray(sym), jnp.asarray(lo), jnp.asarray(hi)
