"""MiningPlan dispatch spine + AOT executable cache (DESIGN.md §11).

Every batched counting entry point in this repo — ``mine_arrays``,
``mine_corpus``, ``StreamingMiner.append``, and the batch/corpus paths in
``core/counting.py`` — used to be an independently-jitted function: each
unseen input shape paid a fresh trace+compile, and ragged traffic (ROADMAP
item 5, the PR 4 corpus bench) spent more time in XLA than in kernels.

This module collapses them onto ONE abstraction:

* :class:`MiningPlan` — a frozen, hashable description of a counting
  launch: the *capacity-class bucket* (episode level, table width, batch
  rows, corpus streams — each rounded up to a power of two by
  :func:`capacity_class`, the same rounding rule ``kernels.autotune``'s
  ``bucket_key`` uses, imported from here so tile tuning and plan
  bucketing can never diverge) plus the resolved engine, tile/chunk
  config, scheduler flavor, and (for the sharded path) mesh.

* an **AOT executable cache** — one ``jax.jit(fn).lower(specs).compile()``
  per (plan, function), held in an LRU with a configurable bound and
  hit/miss/eviction counters. Entry points become thin adapters: resolve
  the plan, pad inputs to the bucket (+inf times / repeated candidate
  rows, both already inert by the padding conventions of DESIGN.md §5),
  call the cached executable, slice the true rows back out. K distinct
  input shapes that fall into k buckets compile exactly k times, ever.

* :func:`warm` — precompile a list of plans so a serving process pays its
  compiles at startup, not on the first live feed (ROADMAP item 1).

Trace accounting: every registered counting function calls
:func:`note_trace` inside its traced body, so one trace == one counter
increment — the O(#buckets) claim is asserted directly in
``tests/test_plan_cache.py`` and measured in ``benchmarks/bench_compile.py``.

Fallbacks never change results: a plan the cache refuses (malformed or
over the configured size bounds) runs through a plain ``jax.jit`` with a
warning; a dispatch reached under an outer trace (e.g. ``count_batch``
jits the index build *and* the counting pass together) inlines the traced
body instead of calling a compiled executable.
"""
from __future__ import annotations

import contextlib
import os
import threading
import warnings
from collections import Counter, OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "MiningPlan", "plan_for", "dispatch", "warm", "register_fn",
    "cache_stats", "reset_cache", "set_cache_size", "cached_plans",
    "cache_disabled", "trace_counts", "plan_trace_counts",
    "reset_trace_counts", "pow2_ceil", "capacity_class", "pad_rows",
    "pad_width", "plans_for_miner",
]


# ---------------------------------------------------------------------------
# The one rounding rule (shared with kernels.autotune.bucket_key)
# ---------------------------------------------------------------------------


def pow2_ceil(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 0) — THE bucketing round.

    ``kernels.autotune`` imports this (as its ``_pow2_ceil``) and
    :func:`plan_for` rounds shapes with it *before* resolving tiles, so a
    plan's bucket and the tuned-tile bucket are the same key by
    construction: the round is idempotent, hence
    ``bucket_key(rounded) == bucket_key(raw)``.
    """
    return 1 << max(0, int(x) - 1).bit_length() if x > 0 else 1


def capacity_class(n: int, floor: int = 1) -> int:
    """Capacity class for a size: pow2_ceil with a lower bound.

    ``floor`` must itself be a power of two (or 1) so every class stays a
    pow2 bucket; callers with a minimum pad (e.g. ``mining.MAX_BATCH_PAD``)
    raise the floor without leaving the shared bucketing scheme.
    """
    return max(int(floor), pow2_ceil(n))


# ---------------------------------------------------------------------------
# MiningPlan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MiningPlan:
    """Hashable static-shape bucket + resolved launch config.

    Shape fields are *already rounded* to capacity classes by
    :func:`plan_for`; tile fields are the resolved integers (never None).
    Two calls with shapes in the same class produce equal plans and hence
    share one compiled executable.
    """

    fn: str                  # registered counting function name
    level: int               # episode length N (symbols per candidate row)
    n_types: int             # alphabet size (exact — it is already static)
    cap: int                 # per-type table width, class-rounded
    batch: int               # candidate rows, class-rounded
    streams: int = 0         # corpus stream rows, class-rounded (0 = none)
    tail_cap: int = 0        # tail-view width (semantic, NOT rounded: it
                             # bounds tail_short, so widening would change
                             # results vs the unbucketed path)
    engine: str = "dense"
    parallel_schedule: bool = False
    cap_occ: Optional[int] = None
    max_window: int = 32
    block_next: int = 256    # resolved tiles (autotune bucket of this plan)
    block_prev: int = 256
    window_tiles: int = 0
    chunk: int = 8
    interpret: Optional[bool] = None
    kind: str = "track"      # autotune kernel kind ("count" | "track")
    tile_cap: int = 0        # cap the tile bucket was resolved at (== cap,
                             # except the tail path which tiles tail_cap)
    mesh: Any = None         # jax Mesh for the sharded path (cache bypass)

    def autotune_key(self) -> str:
        """The tuned-tile bucket this plan resolves through — plan bucket
        and tile bucket are the same key (regression-tested against every
        entry in ``kernels/tuned_configs.json``)."""
        try:
            from ..kernels import autotune
        except ImportError:
            return (f"{self.kind}:L{self.level - 1}:N{pow2_ceil(self.tile_cap)}"
                    f":B{pow2_ceil(max(self.streams, 1) * self.batch)}")
        return autotune.bucket_key(
            self.kind, self.level - 1, self.tile_cap,
            max(self.streams, 1) * self.batch)


def resolve_tiles(
    engine,
    levels: int,
    cap: int,
    batch: int,
    *,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    chunk: Optional[int] = None,
    kind: Optional[str] = None,
) -> Tuple[int, int, int, int, str]:
    """(block_next, block_prev, window_tiles, chunk, kind) for one launch.

    ``None`` knobs resolve through the autotune bucket table — kind
    ``"count"`` when the engine counts natively, ``"track"`` otherwise;
    explicit integers win field-by-field. Pure trace-time work.
    """
    from . import tracking  # deferred: avoid import cycles at module init
    eng = tracking.get_engine(engine) if isinstance(engine, str) else engine
    if kind is None:
        kind = ("count" if getattr(eng, "count_batch", None) is not None
                else "track")
    try:
        from ..kernels import autotune  # deferred: core importable sans pallas
    except ImportError:
        return (256 if block_next is None else int(block_next),
                256 if block_prev is None else int(block_prev),
                0 if window_tiles is None else int(window_tiles),
                8 if chunk is None else int(chunk), kind)
    cfg = autotune.resolve(
        kind, levels, cap, batch, block_next=block_next,
        block_prev=block_prev, window_tiles=window_tiles, chunk=chunk)
    return cfg.block_next, cfg.block_prev, cfg.window_tiles, cfg.chunk, kind


def plan_for(
    fn: str,
    *,
    level: int,
    n_types: int,
    cap: int,
    batch: int,
    streams: int = 0,
    tail_cap: int = 0,
    engine: str = "dense",
    parallel_schedule: bool = False,
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    mesh: Any = None,
    kind: Optional[str] = None,
) -> MiningPlan:
    """Resolve a :class:`MiningPlan`: round shapes to capacity classes,
    then resolve tiles on the *rounded* shapes (idempotent pow2 rounding
    makes the tile bucket identical to the raw-shape bucket)."""
    cap_b = capacity_class(cap)
    batch_b = pow2_ceil(batch)
    streams_b = pow2_ceil(streams) if streams else 0
    tile_cap = (int(tail_cap)
                if fn in ("count_tail", "count_corpus_tail",
                          "count_corpus_tail_grouped")
                else cap_b)
    bn, bp, wt, ch, kind = resolve_tiles(
        engine, level - 1, tile_cap, max(streams_b, 1) * batch_b,
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, kind=kind)
    return MiningPlan(
        fn=fn, level=int(level), n_types=int(n_types), cap=cap_b,
        batch=batch_b, streams=streams_b, tail_cap=int(tail_cap),
        engine=engine, parallel_schedule=bool(parallel_schedule),
        cap_occ=cap_occ, max_window=int(max_window), block_next=bn,
        block_prev=bp, window_tiles=wt, chunk=ch, interpret=interpret,
        kind=kind, tile_cap=tile_cap, mesh=mesh)


# ---------------------------------------------------------------------------
# Padding helpers (adapters pad inputs up to the plan bucket)
# ---------------------------------------------------------------------------


def pad_rows(arr: jax.Array, target: int) -> jax.Array:
    """Pad the leading axis to ``target`` rows by repeating row 0 (the
    existing candidate-pad convention: counted, then discarded)."""
    b = arr.shape[0]
    if b == target:
        return arr
    reps = jnp.broadcast_to(arr[:1], (target - b,) + tuple(arr.shape[1:]))
    return jnp.concatenate([jnp.asarray(arr), reps], axis=0)


def pad_width(arr: jax.Array, target: int, fill) -> jax.Array:
    """Pad the LAST axis to ``target`` with ``fill`` (+inf for time tables
    — inert under every downstream max/searchsorted, DESIGN.md §5)."""
    w = arr.shape[-1]
    if w == target:
        return arr
    pad = jnp.full(tuple(arr.shape[:-1]) + (target - w,), fill,
                   jnp.asarray(arr).dtype)
    return jnp.concatenate([jnp.asarray(arr), pad], axis=-1)


# ---------------------------------------------------------------------------
# Function registry (counting.py registers its builders at import)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _FnEntry:
    build: Callable[[MiningPlan], Callable]       # plan -> traced callable
    specs: Callable[[MiningPlan], Tuple]          # plan -> ShapeDtypeStructs


_FNS: Dict[str, _FnEntry] = {}


def register_fn(name: str, build, specs) -> None:
    """Register a counting function: ``build(plan)`` returns the traced
    callable (static config closed over from the plan), ``specs(plan)``
    its input ShapeDtypeStructs — everything :func:`warm` needs to compile
    without real inputs."""
    _FNS[name] = _FnEntry(build=build, specs=specs)


def _fn_entry(name: str) -> _FnEntry:
    if name not in _FNS:
        from . import counting  # noqa: F401 — importing registers builders
    if name not in _FNS:
        raise KeyError(f"no counting function registered as {name!r}")
    return _FNS[name]


# ---------------------------------------------------------------------------
# Trace accounting (the O(#buckets) gate)
# ---------------------------------------------------------------------------

_TRACES: Counter = Counter()        # fn name -> traced-body executions
_PLAN_TRACES: Counter = Counter()   # plan -> traced-body executions


def note_trace(plan: MiningPlan) -> None:
    """Called inside every registered fn's traced body: one trace (or
    inline re-trace under an outer jit) == one increment."""
    _TRACES[plan.fn] += 1
    _PLAN_TRACES[plan] += 1


def trace_counts() -> Dict[str, int]:
    return dict(_TRACES)


def plan_trace_counts() -> Dict[MiningPlan, int]:
    return dict(_PLAN_TRACES)


def reset_trace_counts() -> None:
    _TRACES.clear()
    _PLAN_TRACES.clear()


# ---------------------------------------------------------------------------
# AOT executable cache
# ---------------------------------------------------------------------------

#: Size bounds above which a plan is not cached (it still *runs*, through
#: a plain jit with a warning). Monkeypatchable in tests.
MAX_CACHE_LEVEL = 64
MAX_CACHE_BATCH = 1 << 16
MAX_CACHE_CAP = 1 << 22
MAX_CACHE_STREAMS = 1 << 12

_DEFAULT_CACHE_SIZE = 512


class _ExecutableCache:
    """LRU of AOT-compiled executables keyed by MiningPlan."""

    def __init__(self, maxsize: int = _DEFAULT_CACHE_SIZE):
        self.maxsize = int(maxsize)
        self._data: "OrderedDict[MiningPlan, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.fallbacks = 0
        self.bypasses = 0

    def lookup(self, plan: MiningPlan):
        with self._lock:
            exe = self._data.get(plan)
            if exe is not None:
                self._data.move_to_end(plan)
                self.hits += 1
            else:
                self.misses += 1
            return exe

    def peek(self, plan: MiningPlan) -> bool:
        with self._lock:
            return plan in self._data

    def insert(self, plan: MiningPlan, exe) -> None:
        with self._lock:
            self._data[plan] = exe
            self._data.move_to_end(plan)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = self.misses = self.evictions = 0
            self.fallbacks = self.bypasses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "size": len(self._data), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "fallbacks": self.fallbacks,
                "bypasses": self.bypasses,
            }

    def plans(self) -> List[MiningPlan]:
        with self._lock:
            return list(self._data)


_CACHE = _ExecutableCache()

# kill switch: REPRO_PLAN_CACHE=0 routes every dispatch through plain jit
_DISABLED = os.environ.get("REPRO_PLAN_CACHE", "1") == "0"


@contextlib.contextmanager
def cache_disabled():
    """Route dispatches through fresh ``jax.jit`` calls (the uncached
    reference path — bit-for-bit parity with the cache is tested against
    exactly this)."""
    global _DISABLED
    prev = _DISABLED
    _DISABLED = True
    try:
        yield
    finally:
        _DISABLED = prev


def cache_stats() -> Dict[str, int]:
    """Executable-cache counters: size/maxsize, hits, misses, evictions,
    fallbacks (uncacheable plans run via plain jit) and bypasses (mesh
    plans dispatched through jax's own jit cache)."""
    return _CACHE.stats()


def cached_plans() -> List[MiningPlan]:
    """The plans currently holding a compiled executable (LRU order)."""
    return _CACHE.plans()


def set_cache_size(maxsize: int) -> None:
    """Shrink/grow the LRU bound; shrinking evicts oldest entries now."""
    with _CACHE._lock:
        _CACHE.maxsize = max(1, int(maxsize))
        while len(_CACHE._data) > _CACHE.maxsize:
            _CACHE._data.popitem(last=False)
            _CACHE.evictions += 1


def reset_cache(maxsize: Optional[int] = None) -> None:
    """Drop every cached executable and zero the counters (tests)."""
    _CACHE.clear()
    _CACHE.maxsize = (_DEFAULT_CACHE_SIZE if maxsize is None
                      else max(1, int(maxsize)))


def uncacheable_reason(plan: MiningPlan) -> Optional[str]:
    """Why a plan cannot hold a cached executable (None = cacheable)."""
    if plan.mesh is not None:
        return "mesh plans dispatch through jax's jit cache (shard_map)"
    if plan.level < 2 or plan.n_types < 1 or plan.cap < 1 or plan.batch < 1:
        return (f"malformed plan shape (level={plan.level}, "
                f"n_types={plan.n_types}, cap={plan.cap}, "
                f"batch={plan.batch})")
    if (plan.fn in ("count_tail", "count_corpus_tail",
                    "count_corpus_tail_grouped") and plan.tail_cap < 1):
        return f"malformed tail view (tail_cap={plan.tail_cap})"
    if plan.level > MAX_CACHE_LEVEL:
        return f"level {plan.level} > MAX_CACHE_LEVEL={MAX_CACHE_LEVEL}"
    if plan.batch > MAX_CACHE_BATCH:
        return f"batch {plan.batch} > MAX_CACHE_BATCH={MAX_CACHE_BATCH}"
    if plan.cap > MAX_CACHE_CAP:
        return f"cap {plan.cap} > MAX_CACHE_CAP={MAX_CACHE_CAP}"
    if plan.streams > MAX_CACHE_STREAMS:
        return (f"streams {plan.streams} > "
                f"MAX_CACHE_STREAMS={MAX_CACHE_STREAMS}")
    return None


def note_bypass(plan: MiningPlan) -> None:
    """Record a dispatch that legitimately sidesteps the cache (the mesh
    path compiles through jax's jit cache, keyed by the same static args a
    plan carries)."""
    _CACHE.bypasses += 1


def _compile(plan: MiningPlan, entry: _FnEntry):
    return jax.jit(entry.build(plan)).lower(*entry.specs(plan)).compile()


def dispatch(plan: MiningPlan, *args):
    """Run a registered counting function through the executable cache.

    Adapters call this with inputs already padded to the plan bucket.
    Under an outer trace the body is inlined (compiled executables reject
    tracers); uncacheable plans fall back to plain jit with a warning —
    results are identical on every path.
    """
    entry = _fn_entry(plan.fn)
    if any(isinstance(leaf, jax.core.Tracer)
           for leaf in jax.tree_util.tree_leaves(args)):
        return entry.build(plan)(*args)
    if _DISABLED:
        return jax.jit(entry.build(plan))(*args)
    reason = uncacheable_reason(plan)
    if reason is not None:
        warnings.warn(
            f"MiningPlan not cacheable ({reason}); dispatching uncached",
            stacklevel=2)
        _CACHE.fallbacks += 1
        return jax.jit(entry.build(plan))(*args)
    exe = _CACHE.lookup(plan)
    if exe is None:
        exe = _compile(plan, entry)
        _CACHE.insert(plan, exe)
    try:
        return exe(*args)
    except (TypeError, ValueError) as err:  # aval mismatch: adapter misuse
        warnings.warn(
            f"cached executable rejected inputs ({err}); "
            "dispatching uncached", stacklevel=2)
        _CACHE.fallbacks += 1
        return jax.jit(entry.build(plan))(*args)


def warm(plans: Iterable[MiningPlan]) -> Dict[str, int]:
    """Precompile executables for ``plans`` (serving-startup protocol).

    Idempotent: already-cached plans are skipped without touching the
    hit/miss counters; uncacheable plans are skipped with a warning.
    Returns ``{"compiled": n, "cached": n, "skipped": n}``.
    """
    out = {"compiled": 0, "cached": 0, "skipped": 0}
    for plan in plans:
        reason = uncacheable_reason(plan)
        if reason is not None:
            warnings.warn(f"warm: skipping plan ({reason})", stacklevel=2)
            out["skipped"] += 1
            continue
        if _CACHE.peek(plan):
            out["cached"] += 1
            continue
        _CACHE.insert(plan, _compile(plan, _fn_entry(plan.fn)))
        out["compiled"] += 1
    return out


# ---------------------------------------------------------------------------
# Serving helpers
# ---------------------------------------------------------------------------


def plans_for_miner(
    cfg,
    *,
    n_types: int,
    n_events: int,
    batches: Optional[Iterable[int]] = None,
    streaming: bool = False,
    tail_caps: Iterable[int] = (),
) -> List[MiningPlan]:
    """Plans a level-wise miner with this config will dispatch, for
    :func:`warm`. ``cfg`` is a ``MinerConfig`` (duck-typed).

    ``batches`` defaults to every capacity class a candidate batch can
    occupy at level 2 (16 .. class(min(max_candidates, n_types^2)));
    later levels reuse the same classes or go quiet. With ``streaming``,
    the cold-backfill (stateful) plans are included, plus a tail-recount
    plan per entry of ``tail_caps`` (the caller's expected suffix widths —
    a feed's event rate bounds them).
    """
    cap = max(1, n_events) if getattr(cfg, "cap", None) is None else cfg.cap
    if batches is None:
        top = capacity_class(min(cfg.max_candidates, n_types * n_types))
        b = 16
        batches = []
        while b <= top:
            batches.append(b)
            b *= 2
        batches = batches or [top]
    batches = sorted({pow2_ceil(int(b)) for b in batches})
    knobs = dict(
        n_types=n_types, cap=cap, engine=cfg.engine,
        parallel_schedule=cfg.parallel_schedule, cap_occ=cfg.cap_occ,
        max_window=cfg.max_window, block_next=cfg.block_next,
        block_prev=cfg.block_prev, window_tiles=cfg.window_tiles,
        interpret=cfg.interpret)
    plans: List[MiningPlan] = []
    for level in range(2, cfg.max_level + 1):
        for b in batches:
            plans.append(plan_for("count_indexed", level=level, batch=b,
                                  **knobs))
            if streaming:
                plans.append(plan_for("count_stateful", level=level,
                                      batch=b, **knobs))
                for tc in tail_caps:
                    plans.append(plan_for("count_tail", level=level,
                                          batch=b, tail_cap=int(tc),
                                          **knobs))
    return plans
