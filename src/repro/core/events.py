"""Event streams (paper §II-C, Definition 1) and the per-type index.

An event stream is a time-sorted sequence of (event_type, time) pairs. The
paper's pre-processing step ("we first pre-process the entire event stream
noting the positions of events of each event-type", §IV-A) becomes a padded
dense [n_types, cap] table of per-type event times so that every downstream
step is static-shaped and jit/vmap/shard_map friendly.

Padding convention (used consistently across core/ and kernels/):
  * padded *times* are ``+inf``  (so searchsorted keeps them at the tail),
  * padded *values* (latest-start bookkeeping) are ``-inf``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.inf


@dataclasses.dataclass
class EventStream:
    """A finite, time-ordered event sequence.

    Attributes:
      types: int32[n]  event-type ids in ``[0, n_types)``.
      times: float32[n] non-decreasing occurrence times.
      n_types: size of the event-type alphabet (``|xi|``).
    """

    types: jax.Array
    times: jax.Array
    n_types: int

    def __post_init__(self):
        self.types = jnp.asarray(self.types, jnp.int32)
        self.times = jnp.asarray(self.times, jnp.float32)
        if self.types.ndim != 1 or self.times.ndim != 1:
            raise ValueError("types/times must be rank-1")
        if self.types.shape[0] != self.times.shape[0]:
            raise ValueError("types/times length mismatch")

    @property
    def n_events(self) -> int:
        return int(self.types.shape[0])

    def validate(self) -> None:
        """Host-side sanity checks (not jittable)."""
        t = np.asarray(self.times)
        if t.size and np.any(np.diff(t) < 0):
            raise ValueError("event times must be non-decreasing")
        ty = np.asarray(self.types)
        if ty.size and (ty.min() < 0 or ty.max() >= self.n_types):
            raise ValueError("event types out of range")


def from_arrays(types, times, n_types: int) -> EventStream:
    s = EventStream(types, times, n_types)
    s.validate()
    return s


def type_index(
    types: jax.Array, times: jax.Array, n_types: int, cap: int
) -> Tuple[jax.Array, jax.Array]:
    """Group event times by type into a padded dense table (jit-compatible).

    Args:
      types: int32[n], times: float32[n] (time-sorted).
      n_types: alphabet size. cap: static per-type capacity (>= 1: a zero
        capacity would make every downstream searchsorted/gather degenerate,
        so an explicit ``cap=0`` is rejected loudly instead of behaving like
        the old falsy-default bug that silently treated it as "unset").

    Returns:
      times_by_type: float32[n_types, cap], each row the (sorted ascending)
        times of that type, padded with +inf. Events beyond ``cap`` per type
        are dropped (callers size ``cap`` from data; ``counts`` reports the
        true totals so overflow is detectable).
      counts: int32[n_types] true per-type event counts (pre-clip).

    Negative type ids are padding (the sharded stream convention, -1) and
    contribute nothing. They must be remapped before the scatters because
    jax scatter indices *wrap* (numpy semantics): a raw ``-1`` would land in
    row ``n_types - 1``, inflating its count and racing +inf writes against
    that type's real times.
    """
    if cap < 1:
        raise ValueError(f"type index cap must be >= 1, got {cap}")
    types = jnp.asarray(types, jnp.int32)
    times = jnp.asarray(times, jnp.float32)
    types = jnp.where(types < 0, n_types, types)   # out of bounds -> dropped
    counts = jnp.zeros((n_types,), jnp.int32).at[types].add(1, mode="drop")
    # Stable grouping: rank of each event within its own type.
    onehot_free_rank = _rank_within_type(types, n_types)
    table = jnp.full((n_types, cap), INF, jnp.float32)
    table = table.at[types, onehot_free_rank].set(times, mode="drop")
    return table, counts


def type_index_update(
    table: jax.Array,    # f32[n_types, cap] existing index (+inf padded)
    counts: jax.Array,   # i32[n_types] true per-type totals so far
    types: jax.Array,    # i32[m] appended chunk, time-sorted, -1 padding
    times: jax.Array,    # f32[m]
) -> Tuple[jax.Array, jax.Array]:
    """Scatter ONE appended chunk into an existing type index (incremental).

    The streaming miner's twin of :func:`type_index`: instead of rebuilding
    the ``[n_types, cap]`` table from the whole stream, only the ``m`` new
    events are ranked (within the chunk) and scattered at offsets
    ``counts[type]`` — O(m log m) work independent of the stream length.
    Because appended times are >= every indexed time and the within-type
    rank is stable, the result is bit-for-bit the table :func:`type_index`
    would build from the concatenated stream (regression-tested).

    Negative types are padding and contribute nothing: they are remapped out
    of bounds *before* the scatters for the same reason as in
    :func:`type_index` (jax scatter wraps, so a raw ``-1`` would corrupt the
    last type's row). Events past ``cap`` per type are dropped from the
    table but still counted — the caller grows the table first
    (:func:`grow_type_index`) when ``counts + chunk`` would overflow.
    """
    n_types = table.shape[0]
    types = jnp.asarray(types, jnp.int32)
    times = jnp.asarray(times, jnp.float32)
    types = jnp.where(types < 0, n_types, types)   # out of bounds -> dropped
    rank = _rank_within_type(types, n_types)
    # clip only the *gather* of per-type offsets (row n_types has no count);
    # the scatters still see the out-of-bounds row and drop it
    pos = counts[jnp.minimum(types, n_types - 1)] + rank
    new_table = table.at[types, pos].set(times, mode="drop")
    new_counts = counts.at[types].add(1, mode="drop")
    return new_table, new_counts


def type_index_update_batch(
    tables: jax.Array,   # f32[S, n_types, cap] per-session indexes
    counts: jax.Array,   # i32[S, n_types] true per-type totals so far
    types: jax.Array,    # i32[S, m] per-session appended chunks, -1 padding
    times: jax.Array,    # f32[S, m]
) -> Tuple[jax.Array, jax.Array]:
    """Scatter one appended chunk per session into a pool of type indexes.

    The session-axis twin of :func:`type_index_update` (one vmapped pass, so
    a serving flush pays one device program for the whole session pool).
    Sessions with nothing to absorb this round pass all-padding rows
    (``-1`` types): padding is remapped out of bounds before the scatters,
    so their table and counts rows ride through bit-for-bit unchanged.
    """
    return jax.vmap(type_index_update)(
        jnp.asarray(tables, jnp.float32), jnp.asarray(counts, jnp.int32),
        jnp.asarray(types, jnp.int32), jnp.asarray(times, jnp.float32))


def grow_type_index(table: jax.Array, new_cap: int) -> jax.Array:
    """Widen a type index to ``new_cap`` columns (+inf fill, contents kept).

    The streaming miner grows capacity *geometrically* (see
    ``streaming.StreamingMiner``), so reallocation (and the recompile a new
    static width implies) happens O(log n) times over a stream's life.
    """
    n_types, cap = table.shape
    if new_cap < cap:
        raise ValueError(f"cannot shrink type index: {cap} -> {new_cap}")
    if new_cap == cap:
        return table
    pad = jnp.full((n_types, new_cap - cap), INF, jnp.float32)
    return jnp.concatenate([table, pad], axis=1)


def type_index_batch(
    types: jax.Array, times: jax.Array, n_types: int, cap: int
) -> Tuple[jax.Array, jax.Array]:
    """Per-stream type indexes for a padded corpus (jit-compatible).

    Args:
      types: int32[S, L] per-stream event types, ``-1`` padding (the sharded
        stream convention — padding is remapped out of bounds and dropped,
        never scattered into a real row).
      times: float32[S, L] per-stream times, ``+inf`` padding.

    Returns ``(tables f32[S, n_types, cap], counts i32[S, n_types])`` — the
    stream-axis twin of :func:`type_index`, built in one vmapped pass so the
    corpus miner pays one device program for the whole batch of streams.
    """
    return jax.vmap(type_index, in_axes=(0, 0, None, None))(
        jnp.asarray(types, jnp.int32), jnp.asarray(times, jnp.float32),
        n_types, cap)


def _rank_within_type(types: jax.Array, n_types: int) -> jax.Array:
    """rank[i] = #events j<i with types[j]==types[i]; O(n log n), no (n,T) blowup."""
    n = types.shape[0]
    order = jnp.argsort(types, stable=True)            # groups types together
    sorted_types = types[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    # start index of each run of equal type within the sorted order
    starts = jnp.searchsorted(sorted_types, sorted_types, side="left").astype(jnp.int32)
    rank_sorted = idx - starts
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    return rank


def episode_symbol_times(
    times_by_type: jax.Array, counts: jax.Array, symbols
) -> Tuple[jax.Array, jax.Array]:
    """Gather per-symbol padded time rows for one episode.

    Returns (times_by_sym [N, cap], counts_by_sym [N]).
    """
    sym = jnp.asarray(symbols, jnp.int32)
    return times_by_type[sym], counts[sym]
