"""Level-wise (Apriori-style) frequent episode discovery (paper §II-C).

At each level N, candidate N-node episodes are generated from frequent
(N-1)-node episodes by the standard suffix/prefix join (alpha[1:] ==
beta[:-1]); their non-overlapped counts are obtained in one batched
(vmapped) pass over the stream — the counting step the paper accelerates —
and candidates below the frequency threshold are pruned (anti-monotonicity
of the non-overlapped count under sub-episodes guarantees completeness).

The paper's focus is the *later* levels, where few-but-long episodes leave
a one-thread-per-episode scheme under-utilized; here every level uses the
data-parallel counting engines of counting.py, so parallelism is over
(episodes x events) regardless of level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import counting
from .episodes import Episode, episode_batch
from .events import EventStream

MAX_BATCH_PAD = 16  # pad candidate batches to multiples of this to limit recompiles


@dataclasses.dataclass
class MinerConfig:
    t_low: float                 # shared inter-event window (low, high]
    t_high: float
    threshold: int               # minimum non-overlapped count
    level_thresholds: Optional[Dict[int, int]] = None  # per-level override
    max_level: int = 4
    engine: str = "dense"
    cap: Optional[int] = None    # per-type event capacity (default: n_events)
    cap_occ: Optional[int] = None
    max_window: int = 32
    max_candidates: int = 4096   # safety valve per level


@dataclasses.dataclass
class LevelResult:
    episodes: List[Episode]
    counts: List[int]
    n_candidates: int


def _pad_to(n: int) -> int:
    return max(MAX_BATCH_PAD, ((n + MAX_BATCH_PAD - 1) // MAX_BATCH_PAD) * MAX_BATCH_PAD)


def generate_candidates(
    frequent: Sequence[Episode], level: int, cfg: MinerConfig
) -> List[Episode]:
    """Suffix/prefix join of frequent (level-1)-node episodes."""
    if level == 2:
        types = sorted({e.symbols[0] for e in frequent})
        return [
            Episode((a, b), (cfg.t_low,), (cfg.t_high,))
            for a in types
            for b in types
        ][: cfg.max_candidates]
    by_prefix: Dict[Tuple[int, ...], List[Episode]] = {}
    for e in frequent:
        by_prefix.setdefault(e.symbols[:-1], []).append(e)
    out: List[Episode] = []
    for alpha in frequent:
        for beta in by_prefix.get(alpha.symbols[1:], []):
            out.append(
                Episode(
                    alpha.symbols + (beta.symbols[-1],),
                    alpha.t_low + (cfg.t_low,),
                    alpha.t_high + (cfg.t_high,),
                )
            )
            if len(out) >= cfg.max_candidates:
                return out
    return out


def count_candidates(
    stream: EventStream, candidates: Sequence[Episode], cfg: MinerConfig
) -> np.ndarray:
    """Batched counting of equal-length candidates (padded for compile reuse)."""
    if not candidates:
        return np.zeros((0,), np.int32)
    b = len(candidates)
    bp = _pad_to(b)
    padded = list(candidates) + [candidates[0]] * (bp - b)
    sym, lo, hi = episode_batch(padded)
    cap = cfg.cap or max(1, stream.n_events)
    counts, _, overflow = counting.count_batch(
        stream.types, stream.times, sym, lo, hi,
        n_types=stream.n_types, cap=cap, engine=cfg.engine,
        cap_occ=cfg.cap_occ, max_window=cfg.max_window)
    counts = np.asarray(counts)[:b]
    if bool(np.any(np.asarray(overflow)[:b])):
        raise RuntimeError(
            "episode counting overflowed static capacity; raise cap/cap_occ/max_window")
    return counts


def mine(stream: EventStream, cfg: MinerConfig) -> Dict[int, LevelResult]:
    """Run level-wise mining up to cfg.max_level. Returns per-level results."""
    results: Dict[int, LevelResult] = {}

    # level 1: single-type episodes; count = per-type non-overlapped count
    types = np.asarray(stream.types)
    level1_eps, level1_counts = [], []
    binc = np.bincount(types, minlength=stream.n_types)
    for t in range(stream.n_types):
        if binc[t] >= cfg.threshold:
            level1_eps.append(Episode((t,)))
            level1_counts.append(int(binc[t]))
    results[1] = LevelResult(level1_eps, level1_counts, stream.n_types)

    frequent = level1_eps
    for level in range(2, cfg.max_level + 1):
        if not frequent:
            break
        cands = generate_candidates(frequent, level, cfg)
        if not cands:
            results[level] = LevelResult([], [], 0)
            break
        counts = count_candidates(stream, cands, cfg)
        thr = (cfg.level_thresholds or {}).get(level, cfg.threshold)
        keep = [(e, int(c)) for e, c in zip(cands, counts) if c >= thr]
        results[level] = LevelResult(
            [e for e, _ in keep], [c for _, c in keep], len(cands))
        frequent = [e for e, _ in keep]
    return results
