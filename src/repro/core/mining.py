"""Level-wise (Apriori-style) frequent episode discovery (paper §II-C).

At each level N, candidate N-node episodes are generated from frequent
(N-1)-node episodes by the standard suffix/prefix join (alpha[1:] ==
beta[:-1]); their non-overlapped counts are obtained in one batched
(vmapped) pass over the stream — the counting step the paper accelerates —
and candidates below the frequency threshold are pruned (anti-monotonicity
of the non-overlapped count under sub-episodes guarantees completeness).

Device-resident design (DESIGN.md §5): the search loop never materializes
Python episode objects. Candidates live as padded ``i32[B, N]`` symbol
arrays (windows are uniform per MinerConfig, so ``f32[B, N-1]`` windows are
broadcast fills), the suffix/prefix join is a vectorized group-by over
symbol rows (:func:`generate_candidates_arrays`), the per-type event index
is built **once per stream** and reused by every level through
``counting.count_batch_indexed``, and threshold pruning is computed on
device — each level pays exactly one host sync, fetching (counts, keep
mask, overflow) in a single transfer. The classic Episode-list API
(:func:`mine`, :func:`generate_candidates`) remains as a thin wrapper and
as the join's reference implementation.

The paper's focus is the *later* levels, where few-but-long episodes leave
a one-thread-per-episode scheme under-utilized; here every level uses the
data-parallel counting engines of counting.py, so parallelism is over
(episodes x events) regardless of level. With a natively-batched engine
(``dense_pallas_fused``) the whole level is ONE fused kernel launch:
``count_batch_indexed`` dispatches the entire candidate batch through the
engine's ``track_batch`` instead of vmapping B per-episode pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import counting, distributed
from . import events as events_lib
from . import plan as plan_mod
from .episodes import Episode, episode_batch, episodes_from_rows
from .events import EventStream

MAX_BATCH_PAD = 16  # minimum candidate-batch capacity class (see _pad_to)


@dataclasses.dataclass
class MinerConfig:
    t_low: float                 # shared inter-event window (low, high]
    t_high: float
    threshold: int               # minimum non-overlapped count
    level_thresholds: Optional[Dict[int, int]] = None  # per-level override
    max_level: int = 4
    engine: str = "dense"        # any registered tracking engine (tracking.py)
    cap: Optional[int] = None    # per-type event capacity (default: n_events)
    cap_occ: Optional[int] = None
    max_window: int = 32
    parallel_schedule: bool = False  # greedy_parallel (O(log^2 n) depth)
                                     # instead of the lax.scan scheduler
    max_candidates: int = 4096   # safety valve per level
    min_streams: Optional[int] = None  # corpus aggregation: episodes frequent
                                       # in >= this many streams (mine_corpus)
    # Pallas tile shape: None = per-(L, N, B)-bucket tuned tiles from
    # kernels/tuned_configs.json (kernels.autotune; legacy 256/256/0 when no
    # entry exists); explicit integers bypass the tuned table
    block_next: Optional[int] = None
    block_prev: Optional[int] = None
    window_tiles: Optional[int] = None   # 0 = exact full-window coverage
    interpret: Optional[bool] = None  # None = interpret off-TPU
    # multi-device sharding: give a mesh and mine()/mine_arrays() dispatch
    # to mine_sharded (stream sharded over `shard_axis`, every level's
    # candidate batch tracked inside shard_map; see core/distributed.py)
    mesh: Optional[Mesh] = None
    shard_axis: str = "data"
    n_shards: Optional[int] = None   # default: mesh axis size
    halo: int = 256              # events of right-neighbor lookahead per shard


@dataclasses.dataclass
class LevelResult:
    episodes: List[Episode]
    counts: List[int]
    n_candidates: int


@dataclasses.dataclass
class LevelArrays:
    """Array-form per-level result: surviving episodes as symbol rows."""

    symbols: np.ndarray     # i32[F, N] surviving (frequent) episodes
    counts: np.ndarray      # i32[F] their non-overlapped counts
    n_candidates: int       # candidates generated at this level (pre-prune)


def _pad_to(n: int) -> int:
    """Candidate batches pad to capacity classes (pow2, floor 16) — the
    same rounding rule the MiningPlan bucket and ``autotune.bucket_key``
    use (plan.capacity_class), so a miner-padded batch always arrives at
    the counting adapters already bucket-aligned: zero re-padding, and the
    executable cache compiles O(#batch classes) times per level."""
    return plan_mod.capacity_class(n, floor=MAX_BATCH_PAD)


def _resolve_cap(cfg: MinerConfig, stream: EventStream) -> int:
    """Explicit cfg.cap wins even when falsy (`is None`, not `or`: a cap of
    0 must surface as events.type_index's loud ValueError, not silently
    become the per-stream default — the old idiom hid exactly that bug)."""
    return max(1, stream.n_events) if cfg.cap is None else cfg.cap


def generate_candidates(
    frequent: Sequence[Episode], level: int, cfg: MinerConfig
) -> List[Episode]:
    """Suffix/prefix join of frequent (level-1)-node episodes (list form).

    Reference implementation; :func:`generate_candidates_arrays` is the
    vectorized twin used by the miner and must match it element-for-element.
    """
    if level == 2:
        types = sorted({e.symbols[0] for e in frequent})
        return [
            Episode((a, b), (cfg.t_low,), (cfg.t_high,))
            for a in types
            for b in types
        ][: cfg.max_candidates]
    by_prefix: Dict[Tuple[int, ...], List[Episode]] = {}
    for e in frequent:
        by_prefix.setdefault(e.symbols[:-1], []).append(e)
    out: List[Episode] = []
    for alpha in frequent:
        for beta in by_prefix.get(alpha.symbols[1:], []):
            out.append(
                Episode(
                    alpha.symbols + (beta.symbols[-1],),
                    alpha.t_low + (cfg.t_low,),
                    alpha.t_high + (cfg.t_high,),
                )
            )
            if len(out) >= cfg.max_candidates:
                return out
    return out


def generate_candidates_arrays(
    frequent: np.ndarray, level: int, cfg: MinerConfig
) -> np.ndarray:
    """Vectorized suffix/prefix join over symbol rows.

    Args:
      frequent: i32[F, level-1] symbol rows of the frequent episodes from
        the previous level, in discovery order.

    Returns i32[B, level] candidate rows in exactly the order of
    :func:`generate_candidates` (property-tested), truncated to
    ``cfg.max_candidates``.
    """
    f = np.asarray(frequent, np.int32).reshape(-1, max(level - 1, 1))
    if f.shape[0] == 0:
        return np.zeros((0, level), np.int32)
    if level == 2:
        types = np.unique(f[:, 0])            # ascending, deduped
        a = np.repeat(types, types.size)      # a-major, b-minor nesting
        b = np.tile(types, types.size)
        return np.stack([a, b], axis=1).astype(np.int32)[: cfg.max_candidates]
    prefix, suffix = f[:, :-1], f[:, 1:]
    nf = f.shape[0]
    # Dense ids for (N-2)-symbol rows so the join is integer searchsorted.
    _, inv = np.unique(
        np.concatenate([prefix, suffix], axis=0), axis=0, return_inverse=True)
    pref_id, suf_id = inv[:nf], inv[nf:]
    # Betas grouped by prefix id; stable sort keeps discovery order in-group
    # (matches the dict-of-lists insertion order of the reference join).
    order = np.argsort(pref_id, kind="stable")
    lo = np.searchsorted(pref_id[order], suf_id, side="left")
    hi = np.searchsorted(pref_id[order], suf_id, side="right")
    reps = hi - lo                            # betas joined per alpha
    total = int(reps.sum())
    if total == 0:
        return np.zeros((0, level), np.int32)
    alpha_rows = np.repeat(np.arange(nf), reps)
    group_start = np.cumsum(reps) - reps
    within = np.arange(total) - np.repeat(group_start, reps)
    beta_rows = order[np.repeat(lo, reps) + within]
    out = np.concatenate([f[alpha_rows], f[beta_rows, -1:]], axis=1)
    return out.astype(np.int32)[: cfg.max_candidates]


def count_candidates(
    stream: EventStream, candidates: Sequence[Episode], cfg: MinerConfig
) -> np.ndarray:
    """Batched counting of equal-length candidates (padded for compile reuse)."""
    if not candidates:
        return np.zeros((0,), np.int32)
    b = len(candidates)
    bp = _pad_to(b)
    padded = list(candidates) + [candidates[0]] * (bp - b)
    sym, lo, hi = episode_batch(padded)
    cap = _resolve_cap(cfg, stream)
    counts, _, overflow = counting.count_batch(
        stream.types, stream.times, sym, lo, hi,
        n_types=stream.n_types, cap=cap, engine=cfg.engine,
        cap_occ=cfg.cap_occ, max_window=cfg.max_window,
        parallel_schedule=cfg.parallel_schedule,
        block_next=cfg.block_next, block_prev=cfg.block_prev,
        window_tiles=cfg.window_tiles, interpret=cfg.interpret)
    counts = np.asarray(counts)[:b]
    if bool(np.any(np.asarray(overflow)[:b])):
        raise RuntimeError(
            "episode counting overflowed static capacity or truncated a "
            "constraint window; raise cap/cap_occ/max_window/window_tiles")
    return counts


_OVERFLOW_MSG = (
    "episode counting overflowed static capacity or truncated a "
    "constraint window; raise cap/cap_occ/max_window/window_tiles")


def pad_candidate_rows(cands: np.ndarray, level: int, cfg: MinerConfig):
    """Pad a non-empty candidate-row batch to a MAX_BATCH_PAD multiple
    (repeating row 0 — counted, then discarded) and broadcast the uniform
    windows; returns ``(sym, lo, hi)`` device arrays. Shared by the
    single-stream miner and the corpus miner's union frontier."""
    b = cands.shape[0]
    bp = _pad_to(b)
    sym = np.concatenate([cands, np.broadcast_to(cands[:1], (bp - b, level))])
    lo = jnp.full((bp, level - 1), cfg.t_low, jnp.float32)
    hi = jnp.full((bp, level - 1), cfg.t_high, jnp.float32)
    return jnp.asarray(sym), lo, hi


def _padded_level_batch(frequent: np.ndarray, level: int, cfg: MinerConfig):
    """Join + pad one level's candidates: returns ``(cands, sym, lo, hi)``
    where ``sym`` is padded to a MAX_BATCH_PAD multiple (or ``None`` when
    the join is empty) and lo/hi are the broadcast uniform windows."""
    cands = generate_candidates_arrays(frequent, level, cfg)
    if cands.shape[0] == 0:
        return cands, None, None, None
    sym, lo, hi = pad_candidate_rows(cands, level, cfg)
    return cands, sym, lo, hi


def _prune_level(frequent_types: np.ndarray, counts: np.ndarray,
                 n_types: int) -> LevelArrays:
    """Level-1 result from the per-type counts and a frequency threshold."""
    return LevelArrays(frequent_types[:, None],
                       counts[frequent_types].astype(np.int32), n_types)


def _mine_levels(cfg: MinerConfig, level1: LevelArrays,
                 count_level) -> Dict[int, LevelArrays]:
    """The Apriori level loop shared by the local and sharded miners.

    ``count_level(sym, lo, hi) -> (counts_dev, checks)`` counts one padded
    candidate batch on device; ``checks`` is a list of ``(message,
    flags_dev[B])`` pairs raised on when any flag is set. Each level pays
    exactly ONE host sync: counts, keep mask, and every check flag come
    back in a single ``device_get``.
    """
    results = {1: level1}
    frequent = level1.symbols
    for level in range(2, cfg.max_level + 1):
        if frequent.shape[0] == 0:
            break
        cands, sym, lo, hi = _padded_level_batch(frequent, level, cfg)
        b = cands.shape[0]
        if b == 0:
            results[level] = LevelArrays(
                np.zeros((0, level), np.int32), np.zeros((0,), np.int32), 0)
            break
        thr = (cfg.level_thresholds or {}).get(level, cfg.threshold)
        counts_dev, checks = count_level(sym, lo, hi)
        keep_dev = counts_dev >= jnp.int32(thr)             # pruned on device
        # staticcheck: disable=REPRO004 -- THE sanctioned one-sync-per-level
        fetched = jax.device_get(
            (counts_dev[:b], keep_dev[:b])
            + tuple(flags[:b] for _, flags in checks))
        counts_h, keep_h = fetched[0], fetched[1]
        for (message, _), flags_h in zip(checks, fetched[2:]):
            if bool(np.any(flags_h)):
                raise RuntimeError(message)
        frequent = cands[keep_h]
        results[level] = LevelArrays(
            frequent, np.asarray(counts_h)[keep_h].astype(np.int32), b)
    return results


def mine_arrays(stream: EventStream, cfg: MinerConfig) -> Dict[int, LevelArrays]:
    """Device-resident level-wise mining; returns per-level symbol arrays.

    The per-type index is built once; each level runs candidate counting +
    threshold pruning on device and syncs exactly once (counts, keep mask,
    overflow in a single ``device_get``). The candidate join runs on host
    over compact int32 arrays — it is O(B) numpy work between device
    launches, never per-episode Python.

    With ``cfg.mesh`` set, the same search runs sharded over the mesh via
    :func:`mine_sharded` (identical results, differentially tested).
    """
    if cfg.mesh is not None:
        return mine_sharded(stream, cfg)
    cap = _resolve_cap(cfg, stream)
    table, type_counts = events_lib.type_index(
        stream.types, stream.times, stream.n_types, cap)   # built ONCE
    # pad the index ONCE to its capacity class (+inf columns are inert):
    # every level's counting call then lands exactly on its plan bucket —
    # zero per-call padding, and streams of nearby lengths share one
    # cached executable. build_cap keeps overflow semantics at the true
    # build width (plan.py / DESIGN.md §11).
    table = plan_mod.pad_width(table, plan_mod.capacity_class(cap), jnp.inf)

    # level 1: single-type episodes; count = per-type event count
    binc = np.asarray(type_counts)                          # level-1 host sync
    freq_types = np.nonzero(binc >= cfg.threshold)[0].astype(np.int32)

    def count_level(sym, lo, hi):
        counts_dev, _, overflow = counting.count_batch_indexed(
            table, type_counts, sym, lo, hi,
            engine=cfg.engine, cap_occ=cfg.cap_occ, max_window=cfg.max_window,
            parallel_schedule=cfg.parallel_schedule,
            block_next=cfg.block_next, block_prev=cfg.block_prev,
            window_tiles=cfg.window_tiles, interpret=cfg.interpret,
            build_cap=cap)
        return counts_dev, [(_OVERFLOW_MSG, overflow)]

    return _mine_levels(
        cfg, _prune_level(freq_types, binc, stream.n_types), count_level)


def mine_sharded(stream: EventStream, cfg: MinerConfig) -> Dict[int, LevelArrays]:
    """Multi-device level-wise mining on a stream sharded over ``cfg.mesh``.

    The stream is sharded ONCE (halo exchange + per-shard type index in a
    single shard_map pass, :func:`distributed.build_sharded_index`); every
    level then runs its whole candidate batch through the configured
    tracking engine inside shard_map with a cross-shard greedy merge and
    device-side pruning — still exactly one host sync per level, fetching
    (counts, keep mask, halo flags, overflow) in a single ``device_get``.

    Results are identical to :func:`mine_arrays` on the unsharded stream
    (differentially tested); inadequate halo or capacity is raised, never a
    silent undercount.
    """
    if cfg.mesh is None:
        raise ValueError("mine_sharded requires cfg.mesh")
    n_shards = (cfg.mesh.shape[cfg.shard_axis] if cfg.n_shards is None
                else cfg.n_shards)
    ty, tm = distributed.shard_stream(stream.types, stream.times, n_shards)
    index = distributed.build_sharded_index(
        jnp.asarray(ty), jnp.asarray(tm), cfg.mesh, axis=cfg.shard_axis,
        n_types=stream.n_types, halo=cfg.halo)

    binc = np.asarray(index.global_type_counts)             # level-1 host sync
    freq_types = np.nonzero(binc >= cfg.threshold)[0].astype(np.int32)
    halo_msg = ("halo too short for the candidate episodes' max_span; "
                f"raise MinerConfig.halo (got {index.halo} events of "
                "lookahead per shard)")

    def count_level(sym, lo, hi):
        counts_dev, _, short_dev, overflow_dev = (
            distributed.count_sharded_batch_indexed(
                index, sym, lo, hi,
                engine=cfg.engine, cap_occ=cfg.cap_occ,
                max_window=cfg.max_window,
                parallel_schedule=cfg.parallel_schedule,
                block_next=cfg.block_next, block_prev=cfg.block_prev,
                window_tiles=cfg.window_tiles, interpret=cfg.interpret))
        return counts_dev, [(_OVERFLOW_MSG, overflow_dev),
                            (halo_msg, short_dev)]

    return _mine_levels(
        cfg, _prune_level(freq_types, binc, stream.n_types), count_level)


def mine(stream: EventStream, cfg: MinerConfig) -> Dict[int, LevelResult]:
    """Run level-wise mining up to cfg.max_level. Returns per-level results.

    Thin Episode-list wrapper over :func:`mine_arrays` (same search, same
    order, same counts).
    """
    return {
        level: LevelResult(
            episodes_from_rows(la.symbols, cfg.t_low, cfg.t_high) if level > 1
            else [Episode((int(t),)) for t in la.symbols[:, 0]],
            [int(c) for c in la.counts],
            la.n_candidates,
        )
        for level, la in mine_arrays(stream, cfg).items()
    }
