"""Overlap resolution (paper §IV-A, Algorithm 1) — subproblem 2.

Given candidate occurrence intervals sorted by end time, the size of the
largest non-overlapped subset is the classic greedy interval-scheduling
answer. The paper runs this sequentially on the CPU ("contributes only a
very small overhead"). We provide:

* :func:`greedy_scan` — the paper-faithful sequential pass as a
  ``lax.scan`` (O(n) work, O(n) depth).

* :func:`greedy_parallel` — beyond-paper: the same answer in O(n log n)
  work / O(log^2 n) depth via successor binary lifting, so the stitch step
  of multi-pod mining does not serialize at 1000-node scale. For each
  interval i, its greedy successor is the first (end-sorted) interval j with
  ``s_j > e_i`` — found by a sparse-table "first index with value > v"
  descent — and the greedy chain length is counted with doubled jump tables.

Both require input sorted ascending by end time with invalid entries
parked at ``end=+inf, start=-inf`` (the Occurrences convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .tracking import Occurrences, build_sparse_table

NEG = -jnp.inf


def greedy_scan(occ: Occurrences) -> jax.Array:
    """Paper Algorithm 1: sequential greedy count (jittable)."""

    def step(carry, x):
        prev_e, count = carry
        s, e, v = x
        take = v & (s > prev_e)
        return (jnp.where(take, e, prev_e), count + take.astype(jnp.int32)), None

    (_, count), _ = lax.scan(
        step, (jnp.float32(NEG), jnp.int32(0)), (occ.starts, occ.ends, occ.valid)
    )
    return count


def _first_greater(table: jax.Array, values: jax.Array) -> jax.Array:
    """For each v in values: first index i with starts[i] > v (cap if none).

    ``table`` is build_sparse_table(starts). Descends block sizes 2^k,
    skipping any block whose max start is <= v.
    """
    levels, cap = table.shape[0], table.shape[1]
    pos = jnp.zeros(values.shape, jnp.int32)
    for k in range(levels - 1, -1, -1):
        width = jnp.int32(1 << k)
        blockmax = table[k, jnp.clip(pos, 0, cap - 1)]
        advance = (pos + width <= cap) & (blockmax <= values)
        pos = jnp.where(advance, pos + width, pos)
    return pos


def greedy_parallel(occ: Occurrences) -> jax.Array:
    """Beyond-paper parallel scheduler; identical count to greedy_scan."""
    cap = occ.starts.shape[0]
    s = jnp.where(occ.valid, occ.starts, NEG)
    e = jnp.where(occ.valid, occ.ends, jnp.inf)
    table = build_sparse_table(s)

    # successor of interval i = first j with s_j > e_i (j > i holds because
    # s_j <= e_j and ends are sorted); sink index = cap
    nxt = _first_greater(table, e)                      # i32[cap]
    entry = _first_greater(table, jnp.float32(NEG)[None])[0]

    jump = jnp.concatenate([nxt, jnp.array([cap], jnp.int32)])  # [cap+1]; sink -> sink

    # jump tables: tables[k] = successor^(2^k)
    levels = max(1, cap.bit_length())
    tables = [jump]
    for _ in range(1, levels):
        tables.append(tables[-1][tables[-1]])

    # chain length from entry: largest m with successor^m(entry) != sink,
    # accumulated greedily from the largest power of two downward; the count
    # of selected intervals is m + 1 (when the chain is non-empty).
    pos = entry
    jumps = jnp.int32(0)
    for k in range(levels - 1, -1, -1):
        nxt_pos = tables[k][pos]
        take = nxt_pos < cap
        jumps = jumps + jnp.where(take, jnp.int32(1 << k), 0)
        pos = jnp.where(take, nxt_pos, pos)
    return jumps + (entry < cap).astype(jnp.int32)


def greedy_count(occ: Occurrences, parallel: bool = False) -> jax.Array:
    return greedy_parallel(occ) if parallel else greedy_scan(occ)
