"""Overlap resolution (paper §IV-A, Algorithm 1) — subproblem 2.

Given candidate occurrence intervals sorted by end time, the size of the
largest non-overlapped subset is the classic greedy interval-scheduling
answer. The paper runs this sequentially on the CPU ("contributes only a
very small overhead"). We provide:

* :func:`greedy_scan` — the paper-faithful sequential pass as a
  ``lax.scan`` (O(n) work, O(n) depth).

* :func:`greedy_parallel` — beyond-paper: the same answer in O(n log n)
  work / O(log^2 n) depth via successor binary lifting, so the stitch step
  of multi-pod mining does not serialize at 1000-node scale. For each
  interval i, its greedy successor is the first (end-sorted) interval j with
  ``s_j > e_i`` — found by a sparse-table "first index with value > v"
  descent — and the greedy chain length is counted with doubled jump tables.

Both require input sorted ascending by end time with invalid entries
parked at ``end=+inf, start=-inf`` (the Occurrences convention).

Chain-state carry: both schedulers also exist in a *stateful* form
(:func:`greedy_scan_state` / :func:`greedy_parallel_state`, dispatched by
:func:`greedy_state`) that seeds the scan with ``(prev_end, count)`` and
returns the final pair. The greedy is a left fold, so running it over an
end-sorted prefix and carrying the state into the (end-sorted) remainder
gives exactly the whole-list answer — this is the stitch the sharded merge
performs at shard boundaries (core/distributed.py gathers and re-scans) and
the one the streaming miner performs at the old stream end: every appended
chunk's occurrence intervals end at-or-after every cached interval's end,
so ``append`` resumes each episode's cached ``(prev_end, count)`` instead
of re-scheduling the whole history (core/streaming.py, DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .tracking import Occurrences, build_sparse_table

NEG = -jnp.inf


def greedy_scan_state(
    occ: Occurrences, prev_end: jax.Array, count: jax.Array
) -> tuple:
    """Paper Algorithm 1 seeded with carried state; returns the final state.

    ``prev_end`` is the end time of the last interval taken so far (``-inf``
    for a fresh scan) and ``count`` the intervals taken so far; the strict
    ``start > prev_end`` tie rule (DESIGN.md §3) is what makes the carry
    exact at duplicate boundary timestamps.
    """

    def step(carry, x):
        prev_e, cnt = carry
        s, e, v = x
        take = v & (s > prev_e)
        return (jnp.where(take, e, prev_e), cnt + take.astype(jnp.int32)), None

    carry, _ = lax.scan(
        step,
        (jnp.asarray(prev_end, jnp.float32), jnp.asarray(count, jnp.int32)),
        (occ.starts, occ.ends, occ.valid),
    )
    return carry


def greedy_scan(occ: Occurrences) -> jax.Array:
    """Paper Algorithm 1: sequential greedy count (jittable)."""
    _, count = greedy_scan_state(occ, jnp.float32(NEG), jnp.int32(0))
    return count


def _first_greater(table: jax.Array, values: jax.Array) -> jax.Array:
    """For each v in values: first index i with starts[i] > v (cap if none).

    ``table`` is build_sparse_table(starts). Descends block sizes 2^k,
    skipping any block whose max start is <= v.
    """
    levels, cap = table.shape[0], table.shape[1]
    pos = jnp.zeros(values.shape, jnp.int32)
    for k in range(levels - 1, -1, -1):
        width = jnp.int32(1 << k)
        blockmax = table[k, jnp.clip(pos, 0, cap - 1)]
        advance = (pos + width <= cap) & (blockmax <= values)
        pos = jnp.where(advance, pos + width, pos)
    return pos


def greedy_parallel_state(
    occ: Occurrences, prev_end: jax.Array, count: jax.Array
) -> tuple:
    """Binary-lifting scheduler seeded with carried state; returns final state.

    Identical fold to :func:`greedy_scan_state` (the entry point becomes the
    first end-sorted interval with ``start > prev_end`` instead of the first
    valid interval), so the streaming stitch can run either scheduler.
    """
    cap = occ.starts.shape[0]
    prev_end = jnp.asarray(prev_end, jnp.float32)
    s = jnp.where(occ.valid, occ.starts, NEG)
    e = jnp.where(occ.valid, occ.ends, jnp.inf)
    table = build_sparse_table(s)

    # successor of interval i = first j with s_j > e_i (j > i holds because
    # s_j <= e_j and ends are sorted); sink index = cap
    nxt = _first_greater(table, e)                      # i32[cap]
    entry = _first_greater(table, prev_end[None])[0]

    jump = jnp.concatenate([nxt, jnp.array([cap], jnp.int32)])  # [cap+1]; sink -> sink

    # jump tables: tables[k] = successor^(2^k)
    levels = max(1, cap.bit_length())
    tables = [jump]
    for _ in range(1, levels):
        tables.append(tables[-1][tables[-1]])

    # chain length from entry: largest m with successor^m(entry) != sink,
    # accumulated greedily from the largest power of two downward; the count
    # of selected intervals is m + 1 (when the chain is non-empty).
    pos = entry
    jumps = jnp.int32(0)
    for k in range(levels - 1, -1, -1):
        nxt_pos = tables[k][pos]
        take = nxt_pos < cap
        jumps = jumps + jnp.where(take, jnp.int32(1 << k), 0)
        pos = jnp.where(take, nxt_pos, pos)
    took_any = entry < cap
    final_end = jnp.where(took_any, e[jnp.minimum(pos, cap - 1)], prev_end)
    total = jnp.asarray(count, jnp.int32) + jumps + took_any.astype(jnp.int32)
    return final_end, total


def greedy_parallel(occ: Occurrences) -> jax.Array:
    """Beyond-paper parallel scheduler; identical count to greedy_scan."""
    _, count = greedy_parallel_state(occ, jnp.float32(NEG), jnp.int32(0))
    return count


def greedy_count(occ: Occurrences, parallel: bool = False) -> jax.Array:
    return greedy_parallel(occ) if parallel else greedy_scan(occ)


def greedy_state(
    occ: Occurrences,
    prev_end: jax.Array,
    count: jax.Array,
    parallel: bool = False,
) -> tuple:
    """Greedy fold over ``occ`` seeded with ``(prev_end, count)``.

    Returns the final ``(prev_end, count)`` — the carry the streaming miner
    caches per episode between appends.
    """
    fn = greedy_parallel_state if parallel else greedy_scan_state
    return fn(occ, prev_end, count)
