"""Sequential FSM episode counters — the paper's CPU baseline (§III-A, Fig 3).

Two implementations:

* :func:`count_fsm_numpy` — exact list-based oracle, a direct transcription of
  the algorithm in [Patnaik et al. 2008] as described in the paper. Used as
  the ground-truth reference for every other counter in this repo.

* :func:`count_fsm_scan` — a jittable ``lax.scan`` port with per-symbol ring
  buffers of static size K (sufficient when no more than K events of a symbol
  fall inside one constraint window). This is the "direct port" whose limited
  parallelism motivates the paper's algorithm transformation; it also powers
  the MapConcat baseline's per-segment state machines.

Tie convention (documented in DESIGN.md): "non-overlapped" is strict — the
next occurrence must *start strictly after* the previous occurrence's end
(paper Algorithm 1 uses ``prev_e < s_i``). The FSM therefore only seeds new
first-symbol events with ``t > last_completion_time``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .episodes import Episode

NEG = -jnp.inf


def count_fsm_numpy(types, times, episode: Episode, return_occurrences: bool = False):
    """Exact serial FSM count of non-overlapped occurrences (oracle).

    For each symbol j, ``lists[j]`` holds times of symbol-j events that extend
    some partial occurrence. On completing the last symbol the count is
    incremented and the whole data structure cleared (paper Fig 3).
    """
    types = np.asarray(types)
    times = np.asarray(times, np.float64)
    n = episode.n
    sym = episode.symbols
    lo, hi = episode.t_low, episode.t_high
    lists = [[] for _ in range(n)]
    count = 0
    prev_completion = -np.inf
    occs = []
    for e, t in zip(types, times):
        completed = False
        # highest position first so an event cannot chain off itself
        for j in range(n - 1, -1, -1):
            if e != sym[j]:
                continue
            if j == 0 and n == 1:
                if t > prev_completion:
                    count += 1
                    prev_completion = t
                    if return_occurrences:
                        occs.append(t)
                continue
            if j == 0:
                if t > prev_completion:
                    lists[0].append(t)
                continue
            ok = any(lo[j - 1] < t - s <= hi[j - 1] for s in lists[j - 1])
            if not ok:
                continue
            if j == n - 1:
                count += 1
                prev_completion = t
                if return_occurrences:
                    occs.append(t)
                lists = [[] for _ in range(n)]
                completed = True
                break
            lists[j].append(t)
        if completed:
            continue
    if return_occurrences:
        return count, occs
    return count


def count_all_occurrences_numpy(types, times, episode: Episode):
    """Exact *superset* enumeration: every (start, end) pair such that some
    valid occurrence starts at ``start`` and ends at ``end``. Exponential in
    principle; per distinct end we keep only the latest start (the dominance
    argument in core/tracking.py). Oracle for the tracking step."""
    types = np.asarray(types)
    times = np.asarray(times, np.float64)
    n = episode.n
    sym, lo, hi = episode.symbols, episode.t_low, episode.t_high
    per_sym = [times[types == s] for s in sym]
    # level 0: latest start of a chain ending at this symbol-0 event = itself
    cur_times = per_sym[0]
    cur_start = per_sym[0].copy()
    for i in range(n - 1):
        nxt = per_sym[i + 1]
        nstart = np.full(nxt.shape, -np.inf)
        for j, t in enumerate(nxt):
            m = (cur_times >= t - hi[i]) & (cur_times < t - lo[i])
            if m.any():
                nstart[j] = cur_start[m].max()
        keep = nstart > -np.inf
        cur_times, cur_start = nxt[keep], nstart[keep]
    return cur_start, cur_times  # (starts, ends), sorted by end


def greedy_numpy(starts, ends) -> int:
    """Paper Algorithm 1 on a host: intervals sorted by end time."""
    count = 0
    prev_e = -np.inf
    for s, e in zip(starts, ends):
        if prev_e < s:
            prev_e = e
            count += 1
    return count


# ---------------------------------------------------------------------------
# Jittable ring-buffer FSM (direct port; limited parallelism by construction)
# ---------------------------------------------------------------------------


def count_fsm_scan(
    types: jax.Array,
    times: jax.Array,
    episode: Episode,
    ring: int = 8,
    t_start: float = -jnp.inf,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``lax.scan`` FSM. Events with time == +inf are padding and ignored.

    Args:
      ring: static per-symbol buffer size; correct iff no more than ``ring``
        same-symbol events are simultaneously "live" inside one constraint
        window (tests size data accordingly; the numpy oracle has no limit).
      t_start: only occurrences *starting strictly after* this time are
        counted (used by MapConcat segment stitching).

    Returns: (count i32, first_end f32, last_end f32) — first/last completed
      occurrence end times (+/-inf when count == 0), the (a, b) bookkeeping of
      paper Fig 4.
    """
    n = episode.n
    sym, lo, hi = episode.as_arrays()
    types = jnp.asarray(types, jnp.int32)
    times = jnp.asarray(times, jnp.float32)

    bufs0 = jnp.full((n, ring), NEG, jnp.float32)   # times per symbol (ring)
    heads0 = jnp.zeros((n,), jnp.int32)
    carry0 = (bufs0, heads0, jnp.float32(t_start), jnp.int32(0),
              jnp.float32(jnp.inf), jnp.float32(NEG))

    def step(carry, ev):
        bufs, heads, prev_e, count, first_end, last_end = carry
        e, t = ev
        valid = jnp.isfinite(t)

        # completion check (position n-1)
        if n == 1:
            completes = valid & (e == sym[0]) & (t > prev_e)
        else:
            win_ok = (bufs[n - 2] > NEG) & (t - bufs[n - 2] > lo[n - 2]) & (
                t - bufs[n - 2] <= hi[n - 2])
            completes = valid & (e == sym[n - 1]) & jnp.any(win_ok)

        # non-completing updates for positions 0..n-2 (masked out on completion)
        new_bufs, new_heads = bufs, heads
        for j in range(n - 1):
            if j == 0:
                add = valid & (e == sym[0]) & (t > prev_e)
            else:
                ok = (bufs[j - 1] > NEG) & (t - bufs[j - 1] > lo[j - 1]) & (
                    t - bufs[j - 1] <= hi[j - 1])
                add = valid & (e == sym[j]) & jnp.any(ok)
            add = add & ~completes
            new_bufs = jnp.where(
                add,
                new_bufs.at[j, new_heads[j]].set(t),
                new_bufs,
            )
            new_heads = jnp.where(
                add, new_heads.at[j].set((new_heads[j] + 1) % ring), new_heads)

        # on completion: clear everything, bump count
        new_bufs = jnp.where(completes, jnp.full_like(bufs, NEG), new_bufs)
        new_heads = jnp.where(completes, jnp.zeros_like(heads), new_heads)
        prev_e = jnp.where(completes, t, prev_e)
        count = count + completes.astype(jnp.int32)
        first_end = jnp.where(completes & (count == 1), t, first_end)
        last_end = jnp.where(completes, t, last_end)
        return (new_bufs, new_heads, prev_e, count, first_end, last_end), None

    (_, _, _, count, first_end, last_end), _ = lax.scan(step, carry0, (types, times))
    return count, first_end, last_end
