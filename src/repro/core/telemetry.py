"""Runtime telemetry mining — the paper's engine on the framework's own
control plane (DESIGN.md §4 point 3).

The distributed runtime emits a typed event stream: per-host slow steps,
collective retries, checkpoint events. Recurring temporal patterns are
exactly the paper's constrained serial episodes, e.g. the straggler
signature ``SLOW(h) -(0, w]-> SLOW(h) -(0, w]-> SLOW(h)``: host h is slow on
three step-adjacent occasions. Mining these with the non-overlapped counter
gives a robust (burst-insensitive) straggler score.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from . import counting
from .episodes import serial
from .events import EventStream


@dataclasses.dataclass
class TelemetryLog:
    """Host-side accumulating event log with a string event vocabulary."""

    vocab: Dict[str, int] = dataclasses.field(default_factory=dict)
    kinds: List[int] = dataclasses.field(default_factory=list)
    times: List[float] = dataclasses.field(default_factory=list)

    def key(self, kind: str) -> int:
        if kind not in self.vocab:
            self.vocab[kind] = len(self.vocab)
        return self.vocab[kind]

    def emit(self, kind: str, t: float) -> None:
        self.kinds.append(self.key(kind))
        self.times.append(float(t))

    def to_stream(self) -> EventStream:
        order = np.argsort(np.asarray(self.times, np.float64), kind="stable")
        kinds = np.asarray(self.kinds, np.int32)[order]
        times = np.asarray(self.times, np.float32)[order]
        return EventStream(kinds, times, n_types=max(1, len(self.vocab)))


def slow_step_events(
    log: TelemetryLog, step_times: Dict[int, Sequence[float]], wall: Sequence[float],
    slow_factor: float = 1.5,
) -> None:
    """Convert per-host step durations into SLOW(h) events.

    step_times: host -> per-step duration; wall: per-step wall-clock stamps.
    A host is 'slow' on a step when its duration exceeds slow_factor x the
    median across hosts for that step.
    """
    hosts = sorted(step_times)
    mat = np.asarray([step_times[h] for h in hosts], np.float64)  # [H, S]
    med = np.median(mat, axis=0)
    for hi_, h in enumerate(hosts):
        for s, (d, m, w) in enumerate(zip(mat[hi_], med, wall)):
            if m > 0 and d > slow_factor * m:
                log.emit(f"SLOW:{h}", w)


def straggler_scores(
    log: TelemetryLog,
    *,
    window: float,
    repeat: int = 3,
    engine: str = "dense",
) -> Dict[str, int]:
    """Non-overlapped count of the repeat-SLOW episode per host.

    A high score means host h keeps being slow in temporally-chained bursts
    — the persistent-straggler signature — as opposed to isolated blips.
    """
    stream = log.to_stream()
    scores: Dict[str, int] = {}
    for kind, tid in log.vocab.items():
        if not kind.startswith("SLOW:"):
            continue
        ep = serial([tid] * repeat, 0.0, window)
        res = counting.count_nonoverlapped(stream, ep, engine=engine)
        scores[kind.split(":", 1)[1]] = int(res.count)
    return scores


def flag_stragglers(
    log: TelemetryLog, *, window: float, repeat: int = 3, min_count: int = 2
) -> List[str]:
    return [h for h, c in straggler_scores(log, window=window, repeat=repeat).items()
            if c >= min_count]


class StragglerSessions:
    """Live straggler scoring through the multi-tenant serving pool.

    The streaming twin of :func:`straggler_scores`: each host is ONE
    session in a :class:`serving.MiningSessionServer` (alphabet = the
    single SLOW type; the chained-SLOW signature is the level-``repeat``
    episode), SLOW timestamps are appended as they are observed, and
    every host's non-overlapped count comes out of ONE batched pool
    flush instead of a per-host ``count_nonoverlapped`` loop over a
    rebuilt stream. Counts are identical: a single-type episode's count
    depends only on that host's SLOW substream.
    """

    def __init__(self, *, window: float, repeat: int = 3,
                 engine: str = "dense", hosts_hint: int = 16):
        from .mining import MinerConfig
        from .serving import MiningSessionServer
        self.repeat = int(repeat)
        # threshold 1: a score of 0 simply reports the episode infrequent
        cfg = MinerConfig(t_low=0.0, t_high=float(window), threshold=1,
                          max_level=self.repeat, engine=engine)
        self.server = MiningSessionServer(1, cfg, max_sessions=hosts_hint)
        self._sid: Dict[str, int] = {}

    def observe(self, host: str, times: Sequence[float]) -> None:
        """Append a chunk of SLOW-event timestamps for ``host`` (buffered;
        the next ``scores()`` read absorbs every host's chunks at once)."""
        times = np.asarray(times, np.float32).reshape(-1)
        sid = self._sid.get(host)
        if sid is None:
            sid = self._sid[host] = self.server.create_session()
        self.server.append(sid, np.zeros(times.shape, np.int32), times)

    def scores(self) -> Dict[str, int]:
        """Per-host non-overlapped chained-SLOW count, from the pool's
        level-``repeat`` serving results (one batched flush)."""
        out: Dict[str, int] = {}
        for host, sid in self._sid.items():
            level = self.server.results(sid).get(self.repeat)
            out[host] = (int(level.counts[0])
                         if level is not None and level.counts.size else 0)
        return out
