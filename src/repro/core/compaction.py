"""Output compaction for parallel local tracking (paper §IV-D/E).

Each tracking "thread" m finds ``counts[m]`` next-events, located at
contiguous window positions ``wlo[m] .. wlo[m]+counts[m]-1`` of the
next-symbol time table. Compaction packs all found events into one
contiguous occurrence list.

GPU -> TPU mapping (see DESIGN.md §2):

* ``count_scan_write`` — the paper's preferred lock-free method (Fig 8):
  pass 1 counts (done by the caller via searchsorted bounds), pass 2 is an
  exclusive prefix-scan of the counts (``jnp.cumsum``; XLA scan is a
  first-class TPU op, the direct analogue of cudppScan), pass 3 writes each
  thread's events at its scanned offset. Order-preserving, so backward
  tracking yields end-time-sorted occurrences with no sort.

* ``flags`` — the CudppCompact analogue (Fig 8's cudppCompact): every thread
  owns a fixed slice of a large (cap_occ × max_window) slot array; valid
  slots are flagged and the flag vector is scan-compacted. Materializes the
  capacity-sized expanded array — the scattered-access cost the paper calls
  out ("the array on which cudppCompact operates is very large").

TPU has no global atomics, so AtomicCompact cannot be ported literally; its
cost profile (no per-level ordering guarantee, one final sort) is reproduced
by forward tracking + ``tracking.sort_by_end`` (see counting.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compact(
    t_sym: jax.Array,      # f32[cap] next-symbol (or prev-symbol) time table
    wlo: jax.Array,        # i32[cap_occ] window start per thread
    counts: jax.Array,     # i32[cap_occ] events found per thread (<= max_window)
    carried: jax.Array,    # f32[cap_occ] per-thread bookkeeping (start/end time)
    *,
    cap_occ: int,
    max_window: int,
    method: str = "count_scan_write",
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compact per-thread windows into a contiguous list.

    Returns (new_times f32[cap_occ], new_carried f32[cap_occ],
             n_out i32, overflow bool).

    ``method`` must name an entry of :data:`METHODS`; anything else raises
    ``ValueError`` naming the registered methods.
    """
    try:
        impl = METHODS[method]
    except KeyError:
        raise ValueError(
            f"unknown compaction method: {method!r}; "
            f"registered methods: {sorted(METHODS)}") from None
    return impl(t_sym, wlo, counts, carried, cap_occ, max_window)


def _gather_windows(t_sym, wlo, counts, max_window):
    cap = t_sym.shape[0]
    w = jnp.arange(max_window, dtype=jnp.int32)
    src = jnp.clip(wlo[:, None] + w[None, :], 0, cap - 1)
    vals = t_sym[src]                                   # [cap_occ, W]
    valid = w[None, :] < counts[:, None]                # [cap_occ, W]
    return vals, valid


def _count_scan_write(t_sym, wlo, counts, carried, cap_occ, max_window):
    # pass 2: exclusive scan of counts -> per-thread output offset
    offs = jnp.cumsum(counts) - counts                   # exclusive prefix sum
    total = offs[-1] + counts[-1]
    overflow = total > cap_occ
    # pass 3: write
    vals, valid = _gather_windows(t_sym, wlo, counts, max_window)
    w = jnp.arange(max_window, dtype=jnp.int32)
    pos = offs[:, None] + w[None, :]
    pos = jnp.where(valid, pos, cap_occ)                 # park invalid off-array
    new_t = jnp.full((cap_occ,), jnp.inf, t_sym.dtype)
    new_c = jnp.full((cap_occ,), jnp.inf, carried.dtype)
    new_t = new_t.at[pos.reshape(-1)].set(vals.reshape(-1), mode="drop")
    carried_b = jnp.broadcast_to(carried[:, None], pos.shape)
    new_c = new_c.at[pos.reshape(-1)].set(carried_b.reshape(-1), mode="drop")
    return new_t, new_c, jnp.minimum(total, cap_occ).astype(jnp.int32), overflow


def _flags(t_sym, wlo, counts, carried, cap_occ, max_window):
    # expanded slot array: thread m owns slots [m*W, (m+1)*W)
    vals, valid = _gather_windows(t_sym, wlo, counts, max_window)
    flat_vals = vals.reshape(-1)
    flat_carried = jnp.broadcast_to(carried[:, None], vals.shape).reshape(-1)
    flags = valid.reshape(-1).astype(jnp.int32)
    dest = jnp.cumsum(flags) - flags                     # exclusive scan over slots
    total = jnp.sum(flags)
    overflow = total > cap_occ
    pos = jnp.where(flags > 0, dest, cap_occ)
    new_t = jnp.full((cap_occ,), jnp.inf, t_sym.dtype)
    new_c = jnp.full((cap_occ,), jnp.inf, carried.dtype)
    new_t = new_t.at[pos].set(flat_vals, mode="drop")
    new_c = new_c.at[pos].set(flat_carried, mode="drop")
    return new_t, new_c, jnp.minimum(total, cap_occ).astype(jnp.int32), overflow


#: Registered compaction strategies — the validated `method` names.
METHODS = {
    "count_scan_write": _count_scan_write,
    "flags": _flags,
}
