"""Multi-pod sharded episode counting and mining — the technique at scale.

The event stream is sharded over the mesh ``data`` axis (time-contiguous
blocks). :func:`build_sharded_index` runs ONE ``shard_map`` pass per stream:

  1. a *halo* of the next ``halo`` events past each shard's right boundary
     is fetched with multi-hop ``lax.ppermute`` (the lesson of the paper's
     MapConcat: boundary occurrences need lookahead bounded by
     ``episode.max_span`` — and an occurrence may straddle *several* shards,
     so the halo walks as many right neighbors as it needs);
  2. each shard builds its per-type event index over (own + halo) events
     once; every mining level reuses it (the paper's §IV-A pre-processing
     amortization, extended across shards and levels).

Per level, :func:`count_sharded_batch_indexed` runs the whole candidate
batch through any registered tracking engine *inside* ``shard_map`` (the
fused ``dense_pallas_fused`` engine gets the batch in one launch via
``tracking.track_batch_dispatch``), then merges across shards:

  3. each shard keeps only occurrences seeded at its own events
     (``start <= last own event time`` — ties at duplicate boundary
     timestamps are claimed by BOTH sides: a double-claimed interval is
     still a valid global occurrence and the strict greedy cannot take an
     interval twice, whereas the seed's strict ``start < boundary`` rule
     dropped tied occurrences on the floor, undercounting);
  4. per-shard interval lists are ``all_gather``-ed, end-sorted, and
     resolved with the greedy scheduler (sequential or parallel
     binary-lifting) — subproblem 2 stays cheap exactly as the paper
     claims, and the result is replicated so the miner pays ONE host sync
     per level.

Exactness holds when each shard's halo spans ``max_span`` in time past its
boundary or reaches the global end of the stream; otherwise the
*per-episode* ``halo_short`` flag is set (never a silent undercount — the
adequacy check is strict, ``halo_end - boundary > span``, because an event
at exactly ``halo_end`` may be a duplicate timestamp split across the halo
edge). Static capacity misses surface through ``overflow``, same as the
single-device engines. See DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from . import counting
from . import events as events_lib
from . import scheduling, tracking
from .episodes import Episode
from ..compat import shard_map, shard_map_unchecked


def shard_stream(types, times, n_shards: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side prep: pad and reshape a stream into [n_shards, n_local]."""
    types = np.asarray(types, np.int32)
    times = np.asarray(times, np.float32)
    n = types.shape[0]
    n_local = max(1, -(-n // n_shards))
    pt = np.full((n_shards * n_local,), np.inf, np.float32)
    py = np.full((n_shards * n_local,), -1, np.int32)
    pt[:n] = times
    py[:n] = types
    return py.reshape(n_shards, n_local), pt.reshape(n_shards, n_local)


@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Per-shard (own + halo) type index + boundary bookkeeping.

    Built once per stream by :func:`build_sharded_index` and reused by every
    mining level. All leading-``n_shards`` arrays live sharded over the mesh
    axis; ``global_type_counts`` is the exact own-events-only per-type total
    (the miner's level-1 counts).
    """

    table: jax.Array              # f32[n_shards, n_types, cap_view]
    type_counts: jax.Array        # i32[n_shards, n_types] own+halo view totals
    t_own_last: jax.Array         # f32[n_shards] last own event time (-inf if none)
    t_boundary: jax.Array         # f32[n_shards] right neighbor's first event time
    halo_end: jax.Array           # f32[n_shards] last halo time; +inf when the
                                  #   halo reaches the global end of the stream
    global_type_counts: jax.Array  # i32[n_types]
    mesh: Mesh
    axis: str
    halo: int

    @property
    def n_types(self) -> int:
        return self.table.shape[1]

    @property
    def cap_view(self) -> int:
        return self.table.shape[2]


def _clamp_halo(halo: int, n_shards: int, n_local: int) -> int:
    """A halo can never need more than all events to the right — and with
    multiple shards it must fetch at least ONE neighbor event: halo=0 would
    leave ``halo_end`` unobserved and the adequacy check blind, so a
    boundary-straddling occurrence could vanish without the ``halo_short``
    flag (the module contract is flagged, never silent)."""
    if n_shards == 1:
        return 0
    return max(1, min(halo, (n_shards - 1) * n_local))


# staticcheck: disable=REPRO003 -- mesh path: shard_map executables
# live in jax's jit cache by design (plan.uncacheable_reason)
@functools.partial(
    jax.jit, static_argnames=("mesh", "axis", "n_types", "halo"))
def _build_sharded_index_impl(types_sharded, times_sharded, *,
                              mesh, axis, n_types, halo):
    n_shards, n_local = types_sharded.shape
    hops = -(-halo // n_local) if halo else 0
    cap_view = n_local + halo

    def shard_fn(ty_blk, tm_blk):
        ty = ty_blk[0]      # [n_local]
        tm = tm_blk[0]
        idx = lax.axis_index(axis)

        # multi-hop halo: the h-th hop fetches the h-th right neighbor's
        # whole block, so a halo longer than one shard (occurrences that
        # straddle >= 3 shards) still arrives; wrapped-around blocks from
        # past the last shard are masked to padding
        halo_ty = jnp.zeros((0,), ty.dtype)
        halo_tm = jnp.zeros((0,), tm.dtype)
        for h in range(1, hops + 1):
            perm = [(i, (i - h) % n_shards) for i in range(n_shards)]
            bty = lax.ppermute(ty, axis, perm)
            btm = lax.ppermute(tm, axis, perm)
            real = idx < n_shards - h
            halo_ty = jnp.concatenate([halo_ty, jnp.where(real, bty, -1)])
            halo_tm = jnp.concatenate([halo_tm, jnp.where(real, btm, jnp.inf)])
        halo_ty = halo_ty[:halo]
        halo_tm = halo_tm[:halo]

        all_ty = jnp.concatenate([ty, halo_ty])
        all_tm = jnp.concatenate([tm, halo_tm])
        table, counts = events_lib.type_index(all_ty, all_tm, n_types, cap_view)

        own_finite = jnp.isfinite(tm)
        t_own_last = jnp.max(jnp.where(own_finite, tm, -jnp.inf))
        if halo:
            t_boundary = halo_tm[0]
            # a halo covering every shard to my right sees the stream out to
            # its global end — there is nothing past it to miss
            reaches_end = halo >= (n_shards - 1 - idx) * n_local
            halo_end = jnp.where(reaches_end, jnp.inf, halo_tm[halo - 1])
        else:
            t_boundary = jnp.float32(jnp.inf)
            halo_end = jnp.float32(jnp.inf)

        own_ty = jnp.where(ty >= 0, ty, n_types)        # padding -> dropped
        own_counts = jnp.zeros((n_types,), jnp.int32).at[own_ty].add(
            1, mode="drop")
        global_counts = lax.psum(own_counts, axis)

        return (table[None], counts[None], t_own_last[None], t_boundary[None],
                halo_end[None], global_counts[None])

    in_spec = P(axis, None)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(in_spec, in_spec),
        out_specs=(P(axis, None, None), P(axis, None), P(axis), P(axis),
                   P(axis), P(axis, None)),
    )
    return fn(types_sharded, times_sharded)


def build_sharded_index(
    types_sharded: jax.Array,   # i32[n_shards, n_local] (-1 padding)
    times_sharded: jax.Array,   # f32[n_shards, n_local] (+inf padding)
    mesh: Mesh,
    *,
    axis: str = "data",
    n_types: int,
    halo: int = 256,
) -> ShardedIndex:
    """One shard_map pass: halo exchange + per-shard type index, built once."""
    n_shards, n_local = types_sharded.shape
    axis_size = mesh.shape[axis]
    if axis_size != n_shards:
        raise ValueError(f"stream sharded into {n_shards} != mesh axis {axis_size}")
    halo = _clamp_halo(halo, n_shards, n_local)
    table, counts, own_last, boundary, halo_end, global_counts = (
        _build_sharded_index_impl(
            jnp.asarray(types_sharded), jnp.asarray(times_sharded),
            mesh=mesh, axis=axis, n_types=n_types, halo=halo))
    return ShardedIndex(
        table=table, type_counts=counts, t_own_last=own_last,
        t_boundary=boundary, halo_end=halo_end,
        global_type_counts=global_counts[0], mesh=mesh, axis=axis, halo=halo)


# staticcheck: disable=REPRO003 -- mesh path: shard_map executables
# live in jax's jit cache by design (plan.uncacheable_reason)
@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "engine", "cap_occ", "max_window",
                     "parallel_schedule", "block_next", "block_prev",
                     "window_tiles", "interpret"),
)
def _count_sharded_batch_impl(
    table, type_counts, t_own_last, t_boundary, halo_end,
    symbols, t_low, t_high, *,
    mesh, axis, engine, cap_occ, max_window, parallel_schedule,
    block_next, block_prev, window_tiles, interpret,
):
    cap_view = table.shape[2]
    # the sharded path needs raw intervals for ownership masking + the
    # cross-shard merge, so it always tracks (kind="track" tuned tiles) —
    # the single-launch count pipeline cannot serve it
    try:
        from ..kernels import autotune  # deferred: core importable sans pallas
        tc = autotune.resolve(
            "track", symbols.shape[1] - 1, cap_view, symbols.shape[0],
            block_next=block_next, block_prev=block_prev,
            window_tiles=window_tiles)
        block_next, block_prev, window_tiles = (
            tc.block_next, tc.block_prev, tc.window_tiles)
    except ImportError:
        block_next = 256 if block_next is None else block_next
        block_prev = 256 if block_prev is None else block_prev
        window_tiles = 0 if window_tiles is None else window_tiles
    cfg = tracking.EngineConfig(
        cap_occ=cap_occ, max_window=max_window, block_next=block_next,
        block_prev=block_prev, window_tiles=window_tiles, interpret=interpret)

    def shard_fn(tbl, cnt, own_last, boundary, h_end, sym, lo, hi):
        tbl, cnt = tbl[0], cnt[0]
        own_last, boundary, h_end = own_last[0], boundary[0], h_end[0]

        # whole candidate batch through the engine registry (fused kernel
        # when the engine is natively batched) — subproblem 1 per shard
        occ = tracking.track_batch_dispatch(engine, tbl[sym], lo, hi, cfg)

        # ownership: occurrences seeded at my own events. `<=` (not `<`
        # boundary): with duplicate timestamps at a shard boundary, my
        # tied occurrence is invisible to the neighbor, so I must claim it;
        # the neighbor may claim its own identical-time seed too, which is
        # harmless — both are valid global intervals and the strict greedy
        # cannot take two intervals with equal start/end.
        mine = occ.valid & (occ.starts <= own_last)
        starts = jnp.where(mine, occ.starts, -jnp.inf)
        ends = jnp.where(mine, occ.ends, jnp.inf)

        # per-episode halo adequacy: events up to span past the boundary
        # must be in view. Strict `> span` (flag on `== span`): an event at
        # exactly halo_end can be a duplicate timestamp split across the
        # halo edge, with its twin just out of view.
        span = (jnp.sum(hi, axis=-1) if hi.shape[-1]
                else jnp.zeros((hi.shape[0],), jnp.float32))
        short = jnp.isfinite(h_end) & (h_end - boundary <= span)
        short = lax.psum(short.astype(jnp.int32), axis) > 0

        index_overflow = jnp.any(cnt > cap_view)
        overflow = lax.psum(
            (occ.overflow | index_overflow).astype(jnp.int32), axis) > 0
        n_sup = lax.psum(jnp.sum(mine, axis=-1).astype(jnp.int32), axis)

        # cross-shard greedy merge: gather every shard's owned intervals,
        # end-sort per episode, one greedy pass — the stitch step
        g_starts = lax.all_gather(starts, axis)   # [n_shards, B, cap_view]
        g_ends = lax.all_gather(ends, axis)
        b = sym.shape[0]
        g_starts = jnp.moveaxis(g_starts, 0, 1).reshape(b, -1)
        g_ends = jnp.moveaxis(g_ends, 0, 1).reshape(b, -1)
        order = jnp.argsort(g_ends, axis=-1)
        g_starts = jnp.take_along_axis(g_starts, order, axis=-1)
        g_ends = jnp.take_along_axis(g_ends, order, axis=-1)

        def one(st, en):
            merged = tracking.Occurrences(
                st, en, jnp.isfinite(en) & (st > -jnp.inf),
                jnp.int32(0), jnp.bool_(False))
            return scheduling.greedy_count(merged, parallel=parallel_schedule)

        counts = jax.vmap(one)(g_starts, g_ends)
        return counts[None], n_sup[None], short[None], overflow[None]

    # unchecked: the fused engine's pallas_call has no replication rule in
    # the shard_map checker (every output is P(axis)-sharded anyway)
    fn = shard_map_unchecked(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None), P(axis), P(axis),
                  P(axis), P(), P(), P()),
        out_specs=(P(axis, None),) * 4,
    )
    counts, n_sup, short, overflow = fn(
        table, type_counts, t_own_last, t_boundary, halo_end,
        symbols, t_low, t_high)
    return counts[0], n_sup[0], short[0], overflow[0]


def count_sharded_batch_indexed(
    index: ShardedIndex,
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Count a batch of same-length episodes on a pre-built sharded index.

    Returns ``(counts[B], n_superset[B], halo_short[B], overflow[B])`` —
    replicated device values, so the caller pays one host sync for all four.
    ``n_superset`` is the number of owned final-level occurrence intervals
    summed over shards (the size of the merged superset fed to the greedy
    stitch).

    Plan-spine integration (plan.py): a mesh plan is resolved for the
    launch — same rounding rule, same tuned-tile bucket — but dispatch
    stays on jax's own jit cache (``_count_sharded_batch_impl`` keys on
    the identical static args a plan carries, and shard_map executables
    cannot be AOT-held per-bucket the way single-device ones are). The
    bypass is counted in ``plan.cache_stats()["bypasses"]`` so serving
    telemetry still sees every launch.
    """
    from . import plan as plan_mod
    plan_mod.note_bypass(plan_mod.plan_for(
        "count_indexed", level=int(symbols.shape[1]),
        n_types=int(index.table.shape[-2]), cap=int(index.table.shape[-1]),
        batch=int(symbols.shape[0]), engine=engine,
        parallel_schedule=parallel_schedule, cap_occ=cap_occ,
        max_window=max_window, block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, interpret=interpret, mesh=index.mesh,
        kind="track"))
    return _count_sharded_batch_impl(
        index.table, index.type_counts, index.t_own_last, index.t_boundary,
        index.halo_end,
        jnp.asarray(symbols, jnp.int32), jnp.asarray(t_low, jnp.float32),
        jnp.asarray(t_high, jnp.float32),
        mesh=index.mesh, axis=index.axis, engine=engine, cap_occ=cap_occ,
        max_window=max_window, parallel_schedule=parallel_schedule,
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, interpret=interpret)


def count_sharded_batch(
    types_sharded: jax.Array,
    times_sharded: jax.Array,
    symbols: jax.Array,
    t_low: jax.Array,
    t_high: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    n_types: int,
    halo: int = 256,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sharded batch counting end-to-end (index build + count)."""
    index = build_sharded_index(
        types_sharded, times_sharded, mesh, axis=axis, n_types=n_types,
        halo=halo)
    return count_sharded_batch_indexed(index, symbols, t_low, t_high, **kw)


def count_sharded(
    types_sharded: jax.Array,   # i32[n_shards, n_local] (-1 padding)
    times_sharded: jax.Array,   # f32[n_shards, n_local] (+inf padding)
    episode: Episode,
    mesh: Mesh,
    *,
    axis: str = "data",
    n_types: int,
    halo: int = 256,
    engine: str = "dense",
    parallel_schedule: bool = True,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact non-overlapped count of one episode over a sharded stream.

    Returns ``(count i32, halo_short bool, overflow bool)`` — the
    singleton-batch wrapper over :func:`count_sharded_batch`, so the
    ownership rule, halo adequacy, and engine dispatch are the same code
    the batched miner runs. Works on any mesh whose ``axis`` size equals
    ``types_sharded.shape[0]``; other mesh axes see replicated data (the
    same code runs single-pod and multi-pod).
    """
    sym, lo, hi = episode.as_arrays()
    counts, _, short, overflow = count_sharded_batch(
        types_sharded, times_sharded, sym[None], lo[None], hi[None], mesh,
        axis=axis, n_types=n_types, halo=halo, engine=engine,
        parallel_schedule=parallel_schedule, **kw)
    return counts[0], short[0], overflow[0]


def make_count_sharded_jit(episode: Episode, mesh: Mesh, **kw):
    """jit-wrapped sharded counter for repeated use (benchmarks/serving)."""
    fn = functools.partial(count_sharded, episode=episode, mesh=mesh, **kw)
    # staticcheck: disable=REPRO003 -- mesh path (see module note above)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Corpus sharding: the STREAM axis over the mesh (no halo — streams are
# independent, so unlike the time-sharded path above there is no boundary
# occurrence to exchange and no cross-shard greedy merge; each device mines
# its slice of the corpus in complete isolation and the only collective is
# the level-1 type-count assembly the host reads anyway)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CorpusIndex:
    """Per-stream type indexes, stream-sharded over the mesh.

    ``n_streams`` is the real corpus size; rows past it are all-padding
    streams appended so the stream axis divides the mesh axis (they count
    nothing and the host never reads their rows).
    """

    tables: jax.Array        # f32[S_pad, n_types, cap] (stream-sharded)
    type_counts: jax.Array   # i32[S_pad, n_types]
    mesh: Mesh
    axis: str
    n_streams: int

    @property
    def cap(self) -> int:
        return self.tables.shape[2]


def pad_corpus_streams(
    types: np.ndarray, times: np.ndarray, n_shards: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side prep: pad the STREAM axis to a multiple of ``n_shards``
    with all-padding streams (types ``-1``, times ``+inf``)."""
    types = np.asarray(types, np.int32)
    times = np.asarray(times, np.float32)
    n_streams = types.shape[0]
    s_pad = max(1, -(-n_streams // n_shards)) * n_shards
    if s_pad != n_streams:
        pad = s_pad - n_streams
        types = np.concatenate(
            [types, np.full((pad, types.shape[1]), -1, np.int32)])
        times = np.concatenate(
            [times, np.full((pad, times.shape[1]), np.inf, np.float32)])
    return types, times


# staticcheck: disable=REPRO003 -- mesh path: shard_map executables
# live in jax's jit cache by design (plan.uncacheable_reason)
@functools.partial(jax.jit, static_argnames=("mesh", "axis", "n_types", "cap"))
def _build_corpus_index_impl(types, times, *, mesh, axis, n_types, cap):
    def shard_fn(ty_blk, tm_blk):
        return events_lib.type_index_batch(ty_blk, tm_blk, n_types, cap)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(axis, None, None), P(axis, None)),
    )
    return fn(types, times)


def build_corpus_index(
    types: np.ndarray,   # i32[S, L] (-1 padding)
    times: np.ndarray,   # f32[S, L] (+inf padding)
    mesh: Mesh,
    *,
    axis: str = "data",
    n_types: int,
    cap: int,
) -> CorpusIndex:
    """One shard_map pass: every shard builds its streams' type indexes.

    No halo exchange happens (or could help): a stream lives wholly on one
    shard, so the per-stream index is exactly the single-device one.
    """
    n_streams = types.shape[0]
    types, times = pad_corpus_streams(types, times, mesh.shape[axis])
    tables, counts = _build_corpus_index_impl(
        jnp.asarray(types), jnp.asarray(times),
        mesh=mesh, axis=axis, n_types=n_types, cap=cap)
    return CorpusIndex(
        tables=tables, type_counts=counts, mesh=mesh, axis=axis,
        n_streams=n_streams)


# staticcheck: disable=REPRO003 -- mesh path: shard_map executables
# live in jax's jit cache by design (plan.uncacheable_reason)
@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "engine", "cap_occ", "max_window",
                     "parallel_schedule", "block_next", "block_prev",
                     "window_tiles", "interpret"),
)
def _count_corpus_sharded_impl(
    tables, type_counts, symbols, t_low, t_high, thresholds, *,
    mesh, axis, engine, cap_occ, max_window, parallel_schedule,
    block_next, block_prev, window_tiles, interpret,
):
    def shard_fn(tbl, cnt, sym, lo, hi, thr):
        # each shard counts its local streams exactly as the single-device
        # corpus counter would — no collective anywhere in the level path
        return counting.count_corpus_indexed(
            tbl, cnt, sym, lo, hi, thr,
            engine=engine, cap_occ=cap_occ, max_window=max_window,
            parallel_schedule=parallel_schedule, block_next=block_next,
            block_prev=block_prev, window_tiles=window_tiles,
            interpret=interpret)

    # unchecked for the same reason as the time-sharded counter: pallas_call
    # has no replication rule in the shard_map checker
    fn = shard_map_unchecked(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None), P(), P(), P(), P(axis)),
        out_specs=(P(axis, None),) * 4,
    )
    return fn(tables, type_counts, symbols, t_low, t_high, thresholds)


def count_corpus_sharded_indexed(
    index: CorpusIndex,
    symbols: jax.Array,     # i32[B, N] shared candidate batch
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    thresholds: jax.Array,  # i32[S_pad] per-stream frequency thresholds
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Stream-sharded corpus counting: the embarrassingly-parallel path.

    Same contract as :func:`counting.count_corpus_indexed` — returns
    ``(counts, keep, n_superset, overflow)``, each ``[S_pad, B]`` and
    stream-sharded over the mesh; the miner's single per-level
    ``device_get`` assembles them. Every per-stream row is bit-for-bit the
    single-device result: no halo, no merge, no tie-breaking exists on this
    axis because no occurrence can cross a stream boundary.
    """
    return _count_corpus_sharded_impl(
        index.tables, index.type_counts, jnp.asarray(symbols, jnp.int32),
        jnp.asarray(t_low, jnp.float32), jnp.asarray(t_high, jnp.float32),
        jnp.asarray(thresholds, jnp.int32),
        mesh=index.mesh, axis=index.axis, engine=engine, cap_occ=cap_occ,
        max_window=max_window, parallel_schedule=parallel_schedule,
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, interpret=interpret)
