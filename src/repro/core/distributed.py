"""Multi-pod sharded episode counting — the technique at 1000-node scale.

The event stream is sharded over the mesh ``data`` axis (time-contiguous
blocks). Inside ``shard_map``:

  1. a *halo* of the first ``halo`` events of the right neighbor is fetched
     with ``lax.ppermute`` (the lesson of the paper's MapConcat: boundary
     occurrences need lookahead bounded by ``episode.max_span``);
  2. each shard runs dense local tracking over (own + halo) events and keeps
     only occurrence intervals that *start* at one of its own events
     (strictly before the neighbor's first event time — the dominance
     argument in tracking.py makes this exact, see DESIGN.md);
  3. per-shard interval lists are ``all_gather``-ed, end-sorted, and resolved
     with the greedy scheduler (sequential or parallel binary-lifting) —
     subproblem 2 stays cheap exactly as the paper claims.

Exactness holds when the halo spans ``episode.max_span`` in time (else the
returned ``halo_short`` flag is set) and per-shard static caps hold.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from . import events as events_lib
from . import scheduling, tracking
from .episodes import Episode
from .. import compat
from ..compat import shard_map


def shard_stream(types, times, n_shards: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side prep: pad and reshape a stream into [n_shards, n_local]."""
    types = np.asarray(types, np.int32)
    times = np.asarray(times, np.float32)
    n = types.shape[0]
    n_local = -(-n // n_shards)
    pt = np.full((n_shards * n_local,), np.inf, np.float32)
    py = np.full((n_shards * n_local,), -1, np.int32)
    pt[:n] = times
    py[:n] = types
    return py.reshape(n_shards, n_local), pt.reshape(n_shards, n_local)


def count_sharded(
    types_sharded: jax.Array,   # i32[n_shards, n_local] (-1 padding)
    times_sharded: jax.Array,   # f32[n_shards, n_local] (+inf padding)
    episode: Episode,
    mesh: Mesh,
    *,
    axis: str = "data",
    n_types: int,
    halo: int = 256,
    parallel_schedule: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Exact non-overlapped count over a sharded stream.

    Returns (count i32, halo_short bool). Works on any mesh whose ``axis``
    size equals ``types_sharded.shape[0]``; all other mesh axes see
    replicated data (so the same code runs single-pod and multi-pod).
    """
    sym, lo, hi = episode.as_arrays()
    n_sym = episode.n
    span = float(episode.max_span)
    n_shards = types_sharded.shape[0]
    n_local = types_sharded.shape[1]
    cap_local = n_local + halo
    axis_size = int(np.prod([mesh.shape[a] for a in [axis]]))
    if axis_size != n_shards:
        raise ValueError(f"stream sharded into {n_shards} != mesh axis {axis_size}")

    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def shard_fn(ty_blk, tm_blk):
        ty = ty_blk[0]      # [n_local]
        tm = tm_blk[0]
        idx = lax.axis_index(axis)
        n_sh = compat.axis_size(axis)

        # halo exchange: my first `halo` events go to my LEFT neighbor, i.e.
        # each shard receives the right neighbor's head block
        perm = [(i, (i - 1) % n_shards) for i in range(n_shards)]
        halo_ty = lax.ppermute(ty[:halo], axis, perm)
        halo_tm = lax.ppermute(tm[:halo], axis, perm)
        is_last = idx == n_sh - 1
        halo_ty = jnp.where(is_last, -1, halo_ty)
        halo_tm = jnp.where(is_last, jnp.inf, halo_tm)

        all_ty = jnp.concatenate([ty, halo_ty])
        all_tm = jnp.concatenate([tm, halo_tm])

        # local tracking over own + halo events
        table, counts = events_lib.type_index(all_ty, all_tm, n_types, cap_local)
        times_by_sym = table[sym]
        occ = tracking.track_dense(times_by_sym, lo, hi)

        # keep only occurrences starting at my own events: start strictly
        # before the neighbor's first event time (boundary ties belong to
        # the right shard, whose own seeds satisfy start >= its first time)
        t_boundary = jnp.where(jnp.isfinite(halo_tm[0]), halo_tm[0], jnp.inf)
        mine = occ.valid & (occ.starts < t_boundary)
        starts = jnp.where(mine, occ.starts, -jnp.inf)
        ends = jnp.where(mine, occ.ends, jnp.inf)

        # halo adequacy: the halo must span `span` past the boundary
        # (or be exhausted because the stream ended)
        halo_end = halo_tm[halo - 1]
        halo_short = jnp.isfinite(halo_end) & (halo_end - t_boundary < span)

        # gather all shards' intervals and resolve overlaps globally
        g_starts = lax.all_gather(starts, axis).reshape(-1)
        g_ends = lax.all_gather(ends, axis).reshape(-1)
        order = jnp.argsort(g_ends)
        occ_all = tracking.Occurrences(
            starts=g_starts[order],
            ends=g_ends[order],
            valid=jnp.isfinite(g_ends[order]) & (g_starts[order] > -jnp.inf),
            n_superset=jnp.sum(mine.astype(jnp.int32)),
            overflow=jnp.any(counts > cap_local),
        )
        count = scheduling.greedy_count(occ_all, parallel=parallel_schedule)
        halo_short = jnp.any(lax.all_gather(halo_short, axis))
        return count[None], halo_short[None]

    in_spec = P(axis, None)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(in_spec, in_spec),
        out_specs=(P(axis), P(axis)),
    )
    counts, short = fn(types_sharded, times_sharded)
    del other_axes
    return counts[0], short[0]


def make_count_sharded_jit(episode: Episode, mesh: Mesh, **kw):
    """jit-wrapped sharded counter for repeated use (benchmarks/serving)."""
    fn = functools.partial(count_sharded, episode=episode, mesh=mesh, **kw)
    return jax.jit(fn)
