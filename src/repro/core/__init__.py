# The paper's primary contribution: non-overlapped counting of serial
# episodes with inter-event constraints, transformed for accelerator
# (TPU/XLA) execution. See DESIGN.md for the GPU->TPU mapping.
from .episodes import Episode, serial, episode_batch, episodes_from_rows
from .events import (EventStream, from_arrays, type_index, type_index_batch,
                     type_index_update, type_index_update_batch,
                     grow_type_index, episode_symbol_times)
from .counting import (CountResult, count_batch, count_batch_indexed,
                       count_batch_indexed_stateful, count_corpus_indexed,
                       count_corpus_tail_grouped, count_corpus_tail_indexed,
                       count_nonoverlapped, count_occurrences,
                       count_tail_batch_indexed)
from .mining import (MinerConfig, LevelResult, LevelArrays, mine, mine_arrays,
                     mine_sharded, generate_candidates,
                     generate_candidates_arrays)
from .corpus import (CorpusResult, aggregate_min_streams, mine_corpus,
                     pad_corpus, union_candidates)
from .streaming import StreamingMiner, clean_chunk, suffix_cutoff
from .serving import MiningSessionServer, StreamingCorpusMiner
from .plan import (MiningPlan, plan_for, warm, cache_stats, cached_plans,
                   cache_disabled, plans_for_miner, capacity_class, pow2_ceil)
from .tracking import (TrackingEngine, EngineConfig, register_engine,
                       get_engine, engine_names)
from .statemachine import (count_fsm_numpy, count_fsm_scan, greedy_numpy,
                           count_all_occurrences_numpy)
from .mapconcat import count_mapconcat
from .distributed import (ShardedIndex, build_sharded_index, count_sharded,
                          count_sharded_batch, count_sharded_batch_indexed,
                          shard_stream, CorpusIndex, build_corpus_index,
                          count_corpus_sharded_indexed)
from . import compaction, scheduling, tracking, telemetry


def __getattr__(name):
    # live registry view (see counting.__getattr__): engines registered at
    # runtime appear in repro.core.ENGINES without re-import
    if name == "ENGINES":
        return tracking.engine_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Episode", "serial", "episode_batch", "episodes_from_rows",
    "EventStream", "from_arrays", "type_index", "type_index_batch",
    "type_index_update", "type_index_update_batch", "grow_type_index",
    "episode_symbol_times",
    "CountResult", "count_batch", "count_batch_indexed",
    "count_batch_indexed_stateful", "count_corpus_indexed",
    "count_corpus_tail_grouped", "count_corpus_tail_indexed",
    "count_nonoverlapped", "count_occurrences", "count_tail_batch_indexed",
    "StreamingMiner", "clean_chunk", "suffix_cutoff",
    "MiningSessionServer", "StreamingCorpusMiner", "ENGINES",
    "MinerConfig", "LevelResult", "LevelArrays", "mine", "mine_arrays",
    "mine_sharded", "generate_candidates", "generate_candidates_arrays",
    "CorpusResult", "aggregate_min_streams", "mine_corpus", "pad_corpus",
    "union_candidates",
    "CorpusIndex", "build_corpus_index", "count_corpus_sharded_indexed",
    "TrackingEngine", "EngineConfig", "register_engine", "get_engine",
    "engine_names",
    "count_fsm_numpy", "count_fsm_scan", "greedy_numpy", "count_all_occurrences_numpy",
    "count_mapconcat", "ShardedIndex", "build_sharded_index", "count_sharded",
    "count_sharded_batch", "count_sharded_batch_indexed", "shard_stream",
    "compaction", "scheduling", "tracking", "telemetry",
    "MiningPlan", "plan_for", "warm", "cache_stats", "cached_plans",
    "cache_disabled", "plans_for_miner", "capacity_class", "pow2_ceil",
    "plan",
]
from . import plan  # noqa: E402  (module handle for stats/reset in tests)
