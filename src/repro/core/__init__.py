# The paper's primary contribution: non-overlapped counting of serial
# episodes with inter-event constraints, transformed for accelerator
# (TPU/XLA) execution. See DESIGN.md for the GPU->TPU mapping.
from .episodes import Episode, serial, episode_batch
from .events import EventStream, from_arrays, type_index, episode_symbol_times
from .counting import CountResult, count_batch, count_nonoverlapped, count_occurrences, ENGINES
from .mining import MinerConfig, LevelResult, mine, generate_candidates
from .statemachine import count_fsm_numpy, count_fsm_scan, greedy_numpy, count_all_occurrences_numpy
from .mapconcat import count_mapconcat
from .distributed import count_sharded, shard_stream
from . import compaction, scheduling, tracking, telemetry

__all__ = [
    "Episode", "serial", "episode_batch",
    "EventStream", "from_arrays", "type_index", "episode_symbol_times",
    "CountResult", "count_batch", "count_nonoverlapped", "count_occurrences", "ENGINES",
    "MinerConfig", "LevelResult", "mine", "generate_candidates",
    "count_fsm_numpy", "count_fsm_scan", "greedy_numpy", "count_all_occurrences_numpy",
    "count_mapconcat", "count_sharded", "shard_stream",
    "compaction", "scheduling", "tracking", "telemetry",
]
