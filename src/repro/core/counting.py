"""Non-overlapped episode counting — the paper's redesigned algorithm (§IV).

``count_nonoverlapped`` = parallel local tracking (subproblem 1) + greedy
overlap resolution (subproblem 2). Tracking is dispatched through the
engine registry in tracking.py:

  engine="dense"                  beyond-paper optimized path (see tracking.py)
  engine="dense_pallas"           dense tracking with each level executed by
                                  the Pallas TPU kernel (kernels/episode_track)
                                  via kernels/ops.py; interpret mode off-TPU
  engine="dense_pallas_fused"     dense tracking for an entire candidate
                                  batch in ONE fused Pallas launch: levels
                                  carried in VMEM scratch, scan offsets
                                  scalar-prefetched, dynamic window walk;
                                  batched dispatch via ``track_batch``
  engine="count_scan_write"       paper's preferred lock-free pipeline:
                                  backward tracking + count/scan/write
                                  compaction; output auto-sorted by end time
  engine="atomic_sort"            AtomicCompact analogue: forward tracking +
                                  count/scan/write offsets (TPU has no global
                                  atomics) + one final end-time sort
  engine="flags"                  CudppCompact analogue: flag-scan compaction
                                  over the expanded slot array

All engines return identical counts (property-tested against the numpy FSM
oracle) and differ only in cost profile, mirroring the paper's Fig 11/12
method comparison. Kernel tiling knobs (``block_next``, ``block_prev``,
``window_tiles``, ``interpret``) thread from every public entry point down
to the engine; non-Pallas engines ignore them. Block knobs default to
``None`` = resolve through ``kernels.autotune`` (per-(L, N, B)-bucket tuned
tiles from ``kernels/tuned_configs.json``, legacy constants when no entry
exists); explicit integers bypass the table entirely.

Counting itself dispatches through :func:`count_batch_dispatch`: engines
exposing the natively-counting ``count_batch`` protocol method (the fused
Pallas engine) run tracking + count_scan_write compaction + the greedy
scheduler in ONE kernel launch per (level, candidate batch) — occurrence
intervals never round-trip through HBM; every other engine takes the
track-then-host-greedy path. Both produce bit-for-bit identical counts and
carried chain state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import events as events_lib
from . import plan as plan_mod
from . import scheduling, tracking
from .episodes import Episode

def __getattr__(name: str):
    # ENGINES is a live view of the registry so engines added through
    # tracking.register_engine show up without re-importing (PEP 562).
    if name == "ENGINES":
        return tracking.engine_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class CountResult:
    count: jax.Array        # i32 non-overlapped occurrence count
    n_superset: jax.Array   # i32 size of the tracked (overlapping) superset
    overflow: jax.Array     # bool static-capacity overflow indicator


def count_occurrences(
    times_by_sym: jax.Array,
    t_low: jax.Array,
    t_high: jax.Array,
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    t_min=None,
) -> CountResult:
    """Count on pre-gathered per-symbol time tables (jit/vmap-friendly core).

    ``t_min`` (optional, traced) restricts the count to occurrences seeded
    at time >= ``t_min`` — equal to counting on the substream of events
    at/after the cutoff, for every engine (see EngineConfig.t_min).
    """
    eng = tracking.get_engine(engine)
    n, cap = times_by_sym.shape[-2], times_by_sym.shape[-1]
    bn, bp, wt, chunk = _resolve_tiles(
        eng, n - 1, cap, 1, block_next, block_prev, window_tiles)
    cfg = tracking.EngineConfig(
        cap_occ=cap_occ, max_window=max_window, block_next=bn,
        block_prev=bp, window_tiles=wt, chunk=chunk, interpret=interpret,
        t_min=t_min)
    count, _, n_superset, overflow = count_batch_dispatch(
        eng, times_by_sym[None], t_low[None], t_high[None],
        *_fresh_carries(1), cfg, parallel_schedule=parallel_schedule)
    return CountResult(
        count=count[0], n_superset=n_superset[0], overflow=overflow[0])


def count_nonoverlapped(
    stream: events_lib.EventStream,
    episode: Episode,
    *,
    engine: str = "dense",
    cap: Optional[int] = None,
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> CountResult:
    """End-to-end count for one episode on one stream (public API)."""
    # `is None`, not `or`: an explicit cap=0 (or any falsy value) must be
    # honored — events.type_index rejects cap < 1 loudly — instead of
    # silently behaving like the unset default
    cap = max(1, stream.n_events) if cap is None else cap
    table, counts = events_lib.type_index(
        stream.types, stream.times, stream.n_types, cap)
    sym, lo, hi = episode.as_arrays()
    times_by_sym, _ = events_lib.episode_symbol_times(table, counts, sym)
    res = count_occurrences(
        times_by_sym, lo, hi, engine=engine, cap_occ=cap_occ,
        max_window=max_window, parallel_schedule=parallel_schedule,
        block_next=block_next, block_prev=block_prev,
        window_tiles=window_tiles, interpret=interpret)
    per_type_overflow = jnp.any(counts > cap)
    return CountResult(res.count, res.n_superset, res.overflow | per_type_overflow)


def _resolve_tiles(eng, levels: int, cap: int, batch: int,
                   block_next, block_prev, window_tiles):
    """(block_next, block_prev, window_tiles, chunk) for one count/track call.

    ``None`` knobs resolve through the autotune bucket table — kind
    ``"count"`` when the engine counts natively (the single-launch pipeline
    has its own tuned shapes), ``"track"`` otherwise; explicit integers win
    field-by-field. Resolution is trace-time only (shapes are static under
    jit), so the hot path pays a dict lookup, nothing more. Thin wrapper
    over :func:`plan.resolve_tiles` — the MiningPlan spine and the direct
    per-episode path must resolve identically.
    """
    bn, bp, wt, chunk, _ = plan_mod.resolve_tiles(
        eng, levels, cap, batch, block_next=block_next,
        block_prev=block_prev, window_tiles=window_tiles)
    return bn, bp, wt, chunk


def count_batch_dispatch(
    engine,                    # str name or TrackingEngine
    times_by_sym: jax.Array,   # f32[..., N, cap] sorted rows, +inf padded
    t_low: jax.Array,          # f32[..., N-1]
    t_high: jax.Array,         # f32[..., N-1]
    prev_end: jax.Array,       # f32[...] greedy carry in
    prev_count: jax.Array,     # i32[...] count carry in
    cfg: tracking.EngineConfig,
    *,
    parallel_schedule: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched counting through any engine — THE one counting dispatch.

    Engines exposing the native ``count_batch`` protocol method (see
    tracking.TrackingEngine) run the whole pipeline — tracking, compaction,
    greedy scheduling — in one kernel launch; everything else tracks via
    :func:`tracking.track_batch_dispatch` and folds the host-side greedy.
    ``cfg.t_min`` is consumed HERE (seed-row restriction), so no engine can
    double-apply it. The two schedulers are bit-identical including carried
    state (property-tested), so the in-kernel fold serves both
    ``parallel_schedule`` settings.

    Returns ``(counts i32[...], end_out f32[...], n_superset i32[...],
    overflow bool[...])`` with the ``(prev_end, prev_count)`` carry folded
    in. Stacked leading dims (a corpus) are folded into one batch axis and
    unfolded on the way out.
    """
    lead = times_by_sym.shape[:-2]
    if len(lead) > 1:
        import math as _math
        rows = _math.prod(lead)
        counts, end_out, nsup, ovf = count_batch_dispatch(
            engine, times_by_sym.reshape((rows,) + times_by_sym.shape[-2:]),
            t_low.reshape((rows,) + t_low.shape[-1:]),
            t_high.reshape((rows,) + t_high.shape[-1:]),
            jnp.reshape(prev_end, rows), jnp.reshape(prev_count, rows),
            cfg, parallel_schedule=parallel_schedule)
        return (counts.reshape(lead), end_out.reshape(lead),
                nsup.reshape(lead), ovf.reshape(lead))
    eng = tracking.get_engine(engine) if isinstance(engine, str) else engine
    times_by_sym, cfg = tracking.consume_seed_restriction(times_by_sym, cfg)
    count_batch = getattr(eng, "count_batch", None)
    if count_batch is not None:
        return count_batch(times_by_sym, t_low, t_high,
                           jnp.asarray(prev_end, jnp.float32),
                           jnp.asarray(prev_count, jnp.int32), cfg)
    occ = tracking.track_batch_dispatch(eng, times_by_sym, t_low, t_high, cfg)
    end_out, count_out = _greedy_batch_state(
        occ, prev_end, prev_count, parallel_schedule=parallel_schedule)
    return count_out, end_out, occ.n_superset, occ.overflow


def _greedy_batch_state(occ, prev_end, prev_count, parallel_schedule):
    """vmap the stateful greedy over batch-leading Occurrences.

    THE one greedy epilogue every batched counter shares — stateless
    callers pass fresh ``(-inf, 0)`` carries and drop the returned ends.
    Returns ``(end_out f32[B], count_out i32[B])``.
    """

    def schedule(starts, ends, valid, pe, pc):
        one = tracking.Occurrences(
            starts, ends, valid, jnp.int32(0), jnp.bool_(False))
        return scheduling.greedy_state(one, pe, pc, parallel=parallel_schedule)

    return jax.vmap(schedule)(
        occ.starts, occ.ends, occ.valid,
        jnp.asarray(prev_end, jnp.float32), jnp.asarray(prev_count, jnp.int32))


def _fresh_carries(batch: int):
    return (jnp.full((batch,), -jnp.inf, jnp.float32),
            jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------------------
# MiningPlan builders: the traced bodies behind the AOT executable cache.
# Each closes over a plan (static config) and reads batch/level/cap from its
# argument shapes; `plan.note_trace` inside the body makes trace counts ==
# compile counts observable (DESIGN.md §11). `build_cap` rides as a TRACED
# i32 scalar: adapters pad tables out to the plan's capacity class with
# +inf, so the overflow check must compare against the width the index was
# *built* at, not the padded width — bit-for-bit the unpadded semantics.
# ---------------------------------------------------------------------------


def _engine_cfg(p: plan_mod.MiningPlan, t_min=None) -> tracking.EngineConfig:
    return tracking.EngineConfig(
        cap_occ=p.cap_occ, max_window=p.max_window, block_next=p.block_next,
        block_prev=p.block_prev, window_tiles=p.window_tiles, chunk=p.chunk,
        interpret=p.interpret, t_min=t_min)


def _build_count_indexed(p: plan_mod.MiningPlan):
    def fn(table, counts, build_cap, symbols, t_low, t_high):
        plan_mod.note_trace(p)
        index_overflow = jnp.any(counts > build_cap)
        batch_counts, _, n_superset, overflow = count_batch_dispatch(
            tracking.get_engine(p.engine), table[symbols], t_low, t_high,
            *_fresh_carries(symbols.shape[0]), _engine_cfg(p),
            parallel_schedule=p.parallel_schedule)
        return batch_counts, n_superset, overflow | index_overflow
    return fn


def _build_count_stateful(p: plan_mod.MiningPlan):
    def fn(table, counts, build_cap, symbols, t_low, t_high,
           prev_end, prev_count):
        plan_mod.note_trace(p)
        index_overflow = jnp.any(counts > build_cap)
        count_out, end_out, n_superset, overflow = count_batch_dispatch(
            tracking.get_engine(p.engine), table[symbols], t_low, t_high,
            prev_end, prev_count, _engine_cfg(p),
            parallel_schedule=p.parallel_schedule)
        return count_out, end_out, n_superset, overflow | index_overflow
    return fn


def _build_count_tail(p: plan_mod.MiningPlan):
    tail_cap = p.tail_cap

    def fn(table, counts, old_counts, build_cap, t_tail_start,
           symbols, t_low, t_high, prev_end, prev_count):
        plan_mod.note_trace(p)
        cap = table.shape[1]
        t_tail_start = jnp.asarray(t_tail_start, jnp.float32)
        # per-type suffix offset: first indexed event at/after the cutoff
        # (one searchsorted over the [n_types, cap] table, not per row)
        suffix_start = jax.vmap(
            lambda row: jnp.searchsorted(row, t_tail_start, side="left"))(
            table).astype(jnp.int32)                       # [n_types]
        starts = suffix_start[symbols]                     # [B, N]
        starts = starts.at[:, -1].set(old_counts[symbols[:, -1]])
        # clip at build_cap, not the padded width: entries past the build
        # width never existed, so they must not inflate the suffix need
        needed = jnp.minimum(counts, build_cap)[symbols] - starts
        tail_short = jnp.any(needed > tail_cap, axis=-1)   # [B]
        idx = starts[:, :, None] + jnp.arange(tail_cap, dtype=jnp.int32)
        view = table[symbols[:, :, None], jnp.minimum(idx, cap - 1)]
        view = jnp.where(idx < cap, view, jnp.inf)         # [B, N, tail_cap]

        index_overflow = jnp.any(counts > build_cap)
        count_out, end_out, n_superset, overflow = count_batch_dispatch(
            tracking.get_engine(p.engine), view, t_low, t_high,
            prev_end, prev_count, _engine_cfg(p, t_min=t_tail_start),
            parallel_schedule=p.parallel_schedule)
        return (count_out, end_out, n_superset,
                overflow | index_overflow, tail_short)
    return fn


def _build_count_corpus(p: plan_mod.MiningPlan):
    def fn(tables, counts, build_cap, symbols, t_low, t_high, thresholds):
        plan_mod.note_trace(p)
        s, b = tables.shape[0], symbols.shape[0]
        index_overflow = jnp.any(counts > build_cap, axis=-1)   # [S]
        eng = tracking.get_engine(p.engine)
        cfg = _engine_cfg(p)
        if getattr(eng, "count_batch", None) is not None:
            # corpus-native counting: (stream, episode) rows fold into ONE
            # single-launch count pipeline call — fresh carries, stateless
            corpus_counts, _, n_superset, overflow = count_batch_dispatch(
                eng, tables[:, symbols],
                jnp.broadcast_to(t_low[None], (s,) + t_low.shape),
                jnp.broadcast_to(t_high[None], (s,) + t_high.shape),
                jnp.full((s, b), -jnp.inf, jnp.float32),
                jnp.zeros((s, b), jnp.int32), cfg,
                parallel_schedule=p.parallel_schedule)
        else:
            occ = tracking.track_corpus_dispatch(
                eng, tables[:, symbols], t_low, t_high, cfg)

            def schedule(starts, ends, valid):
                one = tracking.Occurrences(
                    starts, ends, valid, jnp.int32(0), jnp.bool_(False))
                return scheduling.greedy_count(
                    one, parallel=p.parallel_schedule)

            corpus_counts = jax.vmap(jax.vmap(schedule))(
                occ.starts, occ.ends, occ.valid)
            n_superset, overflow = occ.n_superset, occ.overflow
        keep = corpus_counts >= thresholds.astype(jnp.int32)[:, None]
        return (corpus_counts, keep, n_superset,
                overflow | index_overflow[:, None])
    return fn


def _build_count_corpus_tail(p: plan_mod.MiningPlan):
    tail_cap = p.tail_cap

    def fn(tables, counts, old_counts, build_cap, t_tail_start,
           symbols, t_low, t_high, prev_end, prev_count):
        plan_mod.note_trace(p)
        cap = tables.shape[2]
        s, b = tables.shape[0], symbols.shape[0]
        t_tail_start = jnp.asarray(t_tail_start, jnp.float32)
        # per-(session, type) suffix offset: each session's own cutoff over
        # its own table rows (one nested searchsorted over [S, n_types, cap])
        suffix_start = jax.vmap(
            lambda tbl, t0: jax.vmap(
                lambda row: jnp.searchsorted(row, t0, side="left"))(tbl))(
            tables, t_tail_start).astype(jnp.int32)        # [S, n_types]
        starts = suffix_start[:, symbols]                  # [S, B, N]
        starts = starts.at[:, :, -1].set(old_counts[:, symbols[:, -1]])
        needed = jnp.minimum(counts, build_cap)[:, symbols] - starts
        tail_short = jnp.any(needed > tail_cap, axis=-1)   # [S, B]
        idx = starts[..., None] + jnp.arange(tail_cap, dtype=jnp.int32)
        stream_ix = jnp.arange(s, dtype=jnp.int32)[:, None, None, None]
        view = tables[stream_ix, symbols[None, :, :, None],
                      jnp.minimum(idx, cap - 1)]
        view = jnp.where(idx < cap, view, jnp.inf)     # [S, B, N, tail_cap]
        # no t_min here: each session's seed row already starts at its own
        # suffix_start, so the scalar seed restriction count_tail threads
        # through EngineConfig is a provable no-op on this view (the shift
        # restrict_seed_row computes is 0 for every row) — and a per-session
        # t_min could not ride a single EngineConfig scalar anyway
        index_overflow = jnp.any(counts > build_cap, axis=-1)   # [S]
        count_out, end_out, n_superset, overflow = count_batch_dispatch(
            tracking.get_engine(p.engine), view,
            jnp.broadcast_to(t_low[None], (s,) + t_low.shape),
            jnp.broadcast_to(t_high[None], (s,) + t_high.shape),
            prev_end, prev_count, _engine_cfg(p),
            parallel_schedule=p.parallel_schedule)
        return (count_out, end_out, n_superset,
                overflow | index_overflow[:, None], tail_short)
    return fn


def _build_count_corpus_tail_grouped(p: plan_mod.MiningPlan):
    tail_cap = p.tail_cap

    def fn(tables, counts, old_counts, build_cap, t_tail_start,
           symbols, t_low, t_high, prev_end, prev_count):
        plan_mod.note_trace(p)
        cap = tables.shape[2]
        s = tables.shape[0]
        t_tail_start = jnp.asarray(t_tail_start, jnp.float32)
        suffix_start = jax.vmap(
            lambda tbl, t0: jax.vmap(
                lambda row: jnp.searchsorted(row, t0, side="left"))(tbl))(
            tables, t_tail_start).astype(jnp.int32)        # [S, n_types]
        # symbols are per-session here ([S, B, N], each session its own
        # candidate rows) so every gather pairs session s with ITS symbols
        starts = jax.vmap(lambda ss, sym: ss[sym])(
            suffix_start, symbols)                         # [S, B, N]
        starts = starts.at[:, :, -1].set(
            jax.vmap(lambda oc, last: oc[last])(old_counts, symbols[:, :, -1]))
        totals = jax.vmap(lambda c, sym: c[sym])(
            jnp.minimum(counts, build_cap), symbols)       # [S, B, N]
        needed = totals - starts
        tail_short = jnp.any(needed > tail_cap, axis=-1)   # [S, B]
        idx = starts[..., None] + jnp.arange(tail_cap, dtype=jnp.int32)
        stream_ix = jnp.arange(s, dtype=jnp.int32)[:, None, None, None]
        view = tables[stream_ix, symbols[..., None],
                      jnp.minimum(idx, cap - 1)]
        view = jnp.where(idx < cap, view, jnp.inf)     # [S, B, N, tail_cap]
        # same no-t_min argument as count_corpus_tail: each row's seed view
        # already begins at its own suffix_start, so seed restriction is a
        # provable no-op
        index_overflow = jnp.any(counts > build_cap, axis=-1)   # [S]
        count_out, end_out, n_superset, overflow = count_batch_dispatch(
            tracking.get_engine(p.engine), view,
            jnp.broadcast_to(t_low[None], (s,) + t_low.shape),
            jnp.broadcast_to(t_high[None], (s,) + t_high.shape),
            prev_end, prev_count, _engine_cfg(p),
            parallel_schedule=p.parallel_schedule)
        return (count_out, end_out, n_superset,
                overflow | index_overflow[:, None], tail_short)
    return fn


def _specs_count_indexed(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return (S((p.n_types, p.cap), f32), S((p.n_types,), i32), S((), i32),
            S((p.batch, p.level), i32), S((p.batch, p.level - 1), f32),
            S((p.batch, p.level - 1), f32))


def _specs_count_stateful(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return _specs_count_indexed(p) + (S((p.batch,), f32), S((p.batch,), i32))


def _specs_count_tail(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return (S((p.n_types, p.cap), f32), S((p.n_types,), i32),
            S((p.n_types,), i32), S((), i32), S((), f32),
            S((p.batch, p.level), i32), S((p.batch, p.level - 1), f32),
            S((p.batch, p.level - 1), f32), S((p.batch,), f32),
            S((p.batch,), i32))


def _specs_count_corpus(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return (S((p.streams, p.n_types, p.cap), f32),
            S((p.streams, p.n_types), i32), S((), i32),
            S((p.batch, p.level), i32), S((p.batch, p.level - 1), f32),
            S((p.batch, p.level - 1), f32), S((p.streams,), i32))


def _specs_count_corpus_tail(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return (S((p.streams, p.n_types, p.cap), f32),
            S((p.streams, p.n_types), i32), S((p.streams, p.n_types), i32),
            S((), i32), S((p.streams,), f32),
            S((p.batch, p.level), i32), S((p.batch, p.level - 1), f32),
            S((p.batch, p.level - 1), f32), S((p.streams, p.batch), f32),
            S((p.streams, p.batch), i32))


plan_mod.register_fn("count_indexed", _build_count_indexed,
                     _specs_count_indexed)
plan_mod.register_fn("count_stateful", _build_count_stateful,
                     _specs_count_stateful)
plan_mod.register_fn("count_tail", _build_count_tail, _specs_count_tail)
plan_mod.register_fn("count_corpus", _build_count_corpus, _specs_count_corpus)
def _specs_count_corpus_tail_grouped(p):
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    return (S((p.streams, p.n_types, p.cap), f32),
            S((p.streams, p.n_types), i32), S((p.streams, p.n_types), i32),
            S((), i32), S((p.streams,), f32),
            S((p.streams, p.batch, p.level), i32),
            S((p.batch, p.level - 1), f32), S((p.batch, p.level - 1), f32),
            S((p.streams, p.batch), f32), S((p.streams, p.batch), i32))


plan_mod.register_fn("count_corpus_tail", _build_count_corpus_tail,
                     _specs_count_corpus_tail)
plan_mod.register_fn("count_corpus_tail_grouped",
                     _build_count_corpus_tail_grouped,
                     _specs_count_corpus_tail_grouped)


# ---------------------------------------------------------------------------
# Public batched entries: thin adapters over the MiningPlan dispatch spine.
# Each resolves a plan (shapes rounded to capacity classes), pads inputs to
# the bucket (+inf table columns / repeated candidate rows — both inert by
# the DESIGN.md §5 padding conventions), dispatches the cached executable,
# and slices the true rows back out. Signatures are unchanged from the
# pre-plan jitted versions; `build_cap` is new (default: the incoming table
# width, i.e. exactly the old overflow semantics).
# ---------------------------------------------------------------------------


def _plan_knobs(engine, parallel_schedule, cap_occ, max_window, block_next,
                block_prev, window_tiles, interpret):
    return dict(engine=engine, parallel_schedule=parallel_schedule,
                cap_occ=cap_occ, max_window=max_window, block_next=block_next,
                block_prev=block_prev, window_tiles=window_tiles,
                interpret=interpret)


def count_batch_indexed(
    table: jax.Array,       # f32[n_types, cap] per-type time index
    counts: jax.Array,      # i32[n_types] true per-type totals (pre-clip)
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Count a batch of same-length episodes on a *pre-built* type index.

    The miner builds the index once per stream and calls this for every
    level — the paper's pre-processing amortization extended across the
    whole level-wise search. Returns (counts[B], n_superset[B], overflow[B]).

    Adapter over the MiningPlan spine (plan.py): the (level, cap-class,
    batch-class, engine, knobs) bucket maps to ONE cached AOT executable,
    so ragged shapes compile O(#buckets) times. ``build_cap`` is the width
    the index was built at when the caller pre-padded the table to a
    capacity class (default: the table's width). Counting goes through
    :func:`count_batch_dispatch`: engines exposing the natively-counting
    ``count_batch`` protocol method run tracking + compaction + greedy
    scheduling in ONE kernel launch per (level, batch).
    """
    table = jnp.asarray(table, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    if build_cap is None:
        build_cap = table.shape[1]
    b, n = symbols.shape
    if b == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z, jnp.zeros((0,), bool)
    p = plan_mod.plan_for(
        "count_indexed", level=n, n_types=table.shape[0],
        cap=table.shape[1], batch=b,
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    out = plan_mod.dispatch(
        p, plan_mod.pad_width(table, p.cap, jnp.inf), counts,
        jnp.asarray(build_cap, jnp.int32),
        plan_mod.pad_rows(symbols, p.batch),
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch))
    return tuple(a[:b] for a in out)


def count_batch_indexed_stateful(
    table: jax.Array,       # f32[n_types, cap] per-type time index
    counts: jax.Array,      # i32[n_types] true per-type totals (pre-clip)
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    prev_end: jax.Array,    # f32[B] greedy carry in (-inf for a fresh scan)
    prev_count: jax.Array,  # i32[B] count carry in (0 for a fresh scan)
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`count_batch_indexed` that threads the greedy chain state.

    Same tracking, same counts — but the scheduler is seeded with
    ``(prev_end, prev_count)`` per episode and the final carry is returned,
    so a caller can resume the fold later over intervals that all end at or
    after this call's (the streaming miner's cold *backfill* path: a newly
    frequent candidate is counted once over the whole indexed history with
    a fresh carry, then kept warm by tail-delta recounts).

    Returns ``(counts[B], prev_end[B], n_superset[B], overflow[B])``.
    """
    table = jnp.asarray(table, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    prev_end = jnp.asarray(prev_end, jnp.float32)
    prev_count = jnp.asarray(prev_count, jnp.int32)
    if build_cap is None:
        build_cap = table.shape[1]
    b, n = symbols.shape
    if b == 0:
        zi = jnp.zeros((0,), jnp.int32)
        return zi, jnp.zeros((0,), jnp.float32), zi, jnp.zeros((0,), bool)
    p = plan_mod.plan_for(
        "count_stateful", level=n, n_types=table.shape[0],
        cap=table.shape[1], batch=b,
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    out = plan_mod.dispatch(
        p, plan_mod.pad_width(table, p.cap, jnp.inf), counts,
        jnp.asarray(build_cap, jnp.int32),
        plan_mod.pad_rows(symbols, p.batch),
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch),
        plan_mod.pad_rows(prev_end, p.batch),
        plan_mod.pad_rows(prev_count, p.batch))
    return tuple(a[:b] for a in out)


def count_tail_batch_indexed(
    table: jax.Array,       # f32[n_types, cap] per-type time index (updated)
    counts: jax.Array,      # i32[n_types] per-type totals incl. the new chunk
    old_counts: jax.Array,  # i32[n_types] per-type totals BEFORE the chunk
    t_tail_start: jax.Array,  # f32 scalar: suffix cutoff (t_chunk0 - span)
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    prev_end: jax.Array,    # f32[B] greedy carry through the OLD stream
    prev_count: jax.Array,  # i32[B]
    *,
    tail_cap: int,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Tail-delta recount: only what one appended chunk can change.

    An occurrence ending at a chunk event spans at most ``span = sum(hi)``
    back in time, so every event of every such occurrence lies in the
    stream suffix at/after ``t_tail_start = t_chunk0 - span`` (DESIGN.md
    §9). This entry gathers a ``tail_cap``-wide *view* of each symbol row —
    the suffix events for inner symbols, ONLY the chunk's new events for
    the final symbol (slicing at ``old_counts`` is what keeps duplicate
    boundary timestamps exact: an old end event tied at the chunk's first
    time belongs to the already-cached history, not the delta) — tracks it
    with any registered engine, and folds the resulting intervals onto the
    carried greedy state. Work is O(B * N * tail_cap * log tail_cap),
    independent of the indexed stream length. ``tail_cap`` is semantic (it
    bounds ``tail_short``), so the plan bucket keeps it exact — the
    streaming miner already sizes it in capacity classes.

    Returns ``(counts[B], prev_end[B], n_superset[B], overflow[B],
    tail_short[B])``; ``tail_short`` flags a view too narrow for some
    symbol's suffix (the caller re-runs with a wider ``tail_cap`` — flagged,
    never silently wrong, same convention as every other capacity miss).
    """
    table = jnp.asarray(table, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    old_counts = jnp.asarray(old_counts, jnp.int32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    prev_end = jnp.asarray(prev_end, jnp.float32)
    prev_count = jnp.asarray(prev_count, jnp.int32)
    if build_cap is None:
        build_cap = table.shape[1]
    b, n = symbols.shape
    if b == 0:
        zi = jnp.zeros((0,), jnp.int32)
        zb = jnp.zeros((0,), bool)
        return zi, jnp.zeros((0,), jnp.float32), zi, zb, zb
    p = plan_mod.plan_for(
        "count_tail", level=n, n_types=table.shape[0], cap=table.shape[1],
        batch=b, tail_cap=int(tail_cap),
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    out = plan_mod.dispatch(
        p, plan_mod.pad_width(table, p.cap, jnp.inf), counts, old_counts,
        jnp.asarray(build_cap, jnp.int32),
        jnp.asarray(t_tail_start, jnp.float32),
        plan_mod.pad_rows(symbols, p.batch),
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch),
        plan_mod.pad_rows(prev_end, p.batch),
        plan_mod.pad_rows(prev_count, p.batch))
    return tuple(a[:b] for a in out)


def count_corpus_indexed(
    tables: jax.Array,      # f32[S, n_types, cap] per-stream type indexes
    counts: jax.Array,      # i32[S, n_types] true per-type totals (pre-clip)
    symbols: jax.Array,     # i32[B, N] shared candidate batch
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    thresholds: jax.Array,  # i32[S] per-stream frequency thresholds
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Count one candidate batch against a whole corpus of streams at once.

    The stream axis of the *pre-built* batched type index
    (:func:`events.type_index_batch`) rides through tracking as a fold into
    the candidate-batch dimension (:func:`tracking.track_corpus_dispatch`):
    with a corpus-native engine the entire ``S x B`` grid is ONE kernel
    launch per mining level, and every stream's keep mask is computed on
    device against its own threshold — the corpus miner fetches (counts,
    keep, overflow) for all streams in a single per-level host sync.

    Adapter over the MiningPlan spine: the stream axis rounds to its own
    capacity class (padded streams are all-+inf — they track nothing and
    their rows are sliced away), so corpora of nearby sizes share one
    executable.

    Returns ``(counts i32[S, B], keep bool[S, B], n_superset i32[S, B],
    overflow bool[S, B])``. Per-row results are bit-for-bit what
    :func:`count_batch_indexed` returns for that stream alone — tracking,
    scheduling, and overflow math are per-(stream, episode)-row, so batch
    composition cannot perturb them (differentially tested).
    """
    tables = jnp.asarray(tables, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    thresholds = jnp.asarray(thresholds, jnp.int32)
    if thresholds.shape[0] != tables.shape[0]:
        raise ValueError(
            f"thresholds must have shape ({tables.shape[0]},), got "
            f"{thresholds.shape}")
    if build_cap is None:
        build_cap = tables.shape[2]
    s, b = tables.shape[0], symbols.shape[0]
    if b == 0:
        zi = jnp.zeros((s, 0), jnp.int32)
        zb = jnp.zeros((s, 0), bool)
        return zi, zb, zi, zb
    p = plan_mod.plan_for(
        "count_corpus", level=symbols.shape[1], n_types=tables.shape[1],
        cap=tables.shape[2], batch=b, streams=s,
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    tables = plan_mod.pad_width(tables, p.cap, jnp.inf)
    if p.streams != s:
        # padded streams are empty (+inf index, zero counts, zero
        # thresholds): they count nothing and their rows are sliced away
        tables = jnp.concatenate(
            [tables, jnp.full((p.streams - s,) + tables.shape[1:], jnp.inf,
                              jnp.float32)], axis=0)
        counts = jnp.concatenate(
            [counts, jnp.zeros((p.streams - s, counts.shape[1]), jnp.int32)],
            axis=0)
        thresholds = jnp.concatenate(
            [thresholds, jnp.zeros((p.streams - s,), jnp.int32)], axis=0)
    out = plan_mod.dispatch(
        p, tables, counts, jnp.asarray(build_cap, jnp.int32),
        plan_mod.pad_rows(symbols, p.batch),
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch),
        thresholds)
    return tuple(a[:s, :b] for a in out)


def _pad_cols(arr: jax.Array, target: int) -> jax.Array:
    """Pad axis 1 to ``target`` by repeating column 0 (the carry twin of
    ``plan.pad_rows``: padded candidate rows repeat episode 0, so their
    carries must repeat episode 0's carry — computed, then discarded)."""
    b = arr.shape[1]
    if b == target:
        return jnp.asarray(arr)
    reps = jnp.broadcast_to(jnp.asarray(arr)[:, :1],
                            (arr.shape[0], target - b) + arr.shape[2:])
    return jnp.concatenate([jnp.asarray(arr), reps], axis=1)


def count_corpus_tail_indexed(
    tables: jax.Array,       # f32[S, n_types, cap] per-session type indexes
    counts: jax.Array,       # i32[S, n_types] totals incl. the new chunks
    old_counts: jax.Array,   # i32[S, n_types] totals BEFORE the chunks
    t_tail_start: jax.Array,  # f32[S] per-session suffix cutoffs
    symbols: jax.Array,      # i32[B, N] shared (union) candidate batch
    t_low: jax.Array,        # f32[B, N-1]
    t_high: jax.Array,       # f32[B, N-1]
    prev_end: jax.Array,     # f32[S, B] per-(session, episode) greedy carry
    prev_count: jax.Array,   # i32[S, B]
    *,
    tail_cap: int,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Tail-delta recount of one candidate batch against a session pool.

    The serving miner's workhorse (:class:`serving.StreamingCorpusMiner`):
    :func:`count_tail_batch_indexed` with the stream axis of
    :func:`count_corpus_indexed` — each session's suffix view is cut at its
    OWN ``t_tail_start`` / ``old_counts`` and folded onto its own carried
    greedy state, but the whole ``S x B`` grid dispatches as ONE cached
    executable (with a corpus-native engine, one kernel launch).

    Two degenerate settings make this the only counting entry a serving
    flush needs: ``t_tail_start = -inf`` + ``old_counts = 0`` +
    ``tail_cap = cap`` turns a session's row into exactly the full
    stateful backfill (`count_batch_indexed_stateful` semantics, carries
    out), while finite cutoffs give the warm tail-delta recount. Per-row
    results are bit-for-bit the single-stream entries' (differentially
    tested) — tracking/scheduling/overflow are per-(session, episode)-row.

    Returns ``(counts i32[S, B], prev_end f32[S, B], n_superset i32[S, B],
    overflow bool[S, B], tail_short bool[S, B])``.
    """
    tables = jnp.asarray(tables, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    old_counts = jnp.asarray(old_counts, jnp.int32)
    t_tail_start = jnp.asarray(t_tail_start, jnp.float32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    prev_end = jnp.asarray(prev_end, jnp.float32)
    prev_count = jnp.asarray(prev_count, jnp.int32)
    if build_cap is None:
        build_cap = tables.shape[2]
    s, b = tables.shape[0], symbols.shape[0]
    if b == 0:
        zi = jnp.zeros((s, 0), jnp.int32)
        zb = jnp.zeros((s, 0), bool)
        return zi, jnp.zeros((s, 0), jnp.float32), zi, zb, zb
    p = plan_mod.plan_for(
        "count_corpus_tail", level=symbols.shape[1], n_types=tables.shape[1],
        cap=tables.shape[2], batch=b, streams=s, tail_cap=int(tail_cap),
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    tables = plan_mod.pad_width(tables, p.cap, jnp.inf)
    prev_end = _pad_cols(prev_end, p.batch)
    prev_count = _pad_cols(prev_count, p.batch)
    if p.streams != s:
        # padded sessions are empty (+inf index, zero counts, -inf cutoff):
        # they count nothing and their rows are sliced away
        pad = p.streams - s
        tables = jnp.concatenate(
            [tables, jnp.full((pad,) + tables.shape[1:], jnp.inf,
                              jnp.float32)], axis=0)
        counts = jnp.concatenate(
            [counts, jnp.zeros((pad, counts.shape[1]), jnp.int32)], axis=0)
        old_counts = jnp.concatenate(
            [old_counts, jnp.zeros((pad, old_counts.shape[1]), jnp.int32)],
            axis=0)
        t_tail_start = jnp.concatenate(
            [t_tail_start, jnp.full((pad,), -jnp.inf, jnp.float32)], axis=0)
        prev_end = jnp.concatenate(
            [prev_end, jnp.full((pad, p.batch), -jnp.inf, jnp.float32)],
            axis=0)
        prev_count = jnp.concatenate(
            [prev_count, jnp.zeros((pad, p.batch), jnp.int32)], axis=0)
    out = plan_mod.dispatch(
        p, tables, counts, old_counts, jnp.asarray(build_cap, jnp.int32),
        t_tail_start, plan_mod.pad_rows(symbols, p.batch),
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch),
        prev_end, prev_count)
    return tuple(a[:s, :b] for a in out)


def count_corpus_tail_grouped(
    tables: jax.Array,       # f32[S, n_types, cap] per-session type indexes
    counts: jax.Array,       # i32[S, n_types] totals incl. the new chunks
    old_counts: jax.Array,   # i32[S, n_types] totals BEFORE the chunks
    t_tail_start: jax.Array,  # f32[S] per-session suffix cutoffs
    symbols: jax.Array,      # i32[S, B, N] PER-SESSION candidate rows
    t_low: jax.Array,        # f32[B, N-1]
    t_high: jax.Array,       # f32[B, N-1]
    prev_end: jax.Array,     # f32[S, B] per-(session, row) greedy carry
    prev_count: jax.Array,   # i32[S, B]
    *,
    tail_cap: int,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
    build_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`count_corpus_tail_indexed` with PER-SESSION candidate rows.

    The union layout dispatches every session against every key any session
    wants — fine when frontiers agree, quadratic waste when they diverge
    (the multi-tenant serving regime: row (s, b) is computed whether or not
    session ``s`` ever reads key ``b``). Here ``symbols[s]`` holds session
    ``s``'s OWN b-th candidate, so the dispatched grid is exactly the work
    the pool needs: ``rows == sum_s |frontier_s|`` padded to one batch
    class. Row semantics (suffix cutoffs, carries, overflow/tail_short
    flags) are identical to the union entry — only the pairing changes.

    Sessions with fewer than B rows pad by repeating their row 0 (a
    session with no rows at all pads with type-0 rows); padded cells are
    computed and never read, per the quiet-stream masking rule.

    Returns ``(counts i32[S, B], prev_end f32[S, B], n_superset i32[S, B],
    overflow bool[S, B], tail_short bool[S, B])``.
    """
    tables = jnp.asarray(tables, jnp.float32)
    counts = jnp.asarray(counts, jnp.int32)
    old_counts = jnp.asarray(old_counts, jnp.int32)
    t_tail_start = jnp.asarray(t_tail_start, jnp.float32)
    symbols = jnp.asarray(symbols, jnp.int32)
    t_low = jnp.asarray(t_low, jnp.float32)
    t_high = jnp.asarray(t_high, jnp.float32)
    prev_end = jnp.asarray(prev_end, jnp.float32)
    prev_count = jnp.asarray(prev_count, jnp.int32)
    if build_cap is None:
        build_cap = tables.shape[2]
    s, b = tables.shape[0], symbols.shape[1]
    if b == 0:
        zi = jnp.zeros((s, 0), jnp.int32)
        zb = jnp.zeros((s, 0), bool)
        return zi, jnp.zeros((s, 0), jnp.float32), zi, zb, zb
    p = plan_mod.plan_for(
        "count_corpus_tail_grouped", level=symbols.shape[2],
        n_types=tables.shape[1], cap=tables.shape[2], batch=b, streams=s,
        tail_cap=int(tail_cap),
        **_plan_knobs(engine, parallel_schedule, cap_occ, max_window,
                      block_next, block_prev, window_tiles, interpret))
    tables = plan_mod.pad_width(tables, p.cap, jnp.inf)
    symbols = _pad_cols(symbols, p.batch)
    prev_end = _pad_cols(prev_end, p.batch)
    prev_count = _pad_cols(prev_count, p.batch)
    if p.streams != s:
        pad = p.streams - s
        tables = jnp.concatenate(
            [tables, jnp.full((pad,) + tables.shape[1:], jnp.inf,
                              jnp.float32)], axis=0)
        counts = jnp.concatenate(
            [counts, jnp.zeros((pad, counts.shape[1]), jnp.int32)], axis=0)
        old_counts = jnp.concatenate(
            [old_counts, jnp.zeros((pad, old_counts.shape[1]), jnp.int32)],
            axis=0)
        t_tail_start = jnp.concatenate(
            [t_tail_start, jnp.full((pad,), -jnp.inf, jnp.float32)], axis=0)
        symbols = jnp.concatenate(
            [symbols, jnp.zeros((pad,) + symbols.shape[1:], jnp.int32)],
            axis=0)
        prev_end = jnp.concatenate(
            [prev_end, jnp.full((pad, p.batch), -jnp.inf, jnp.float32)],
            axis=0)
        prev_count = jnp.concatenate(
            [prev_count, jnp.zeros((pad, p.batch), jnp.int32)], axis=0)
    out = plan_mod.dispatch(
        p, tables, counts, old_counts, jnp.asarray(build_cap, jnp.int32),
        t_tail_start, symbols,
        plan_mod.pad_rows(t_low, p.batch), plan_mod.pad_rows(t_high, p.batch),
        prev_end, prev_count)
    return tuple(a[:s, :b] for a in out)


# staticcheck: disable=REPRO003 -- sanctioned outer jit: fuses index build +
# counting in one trace; plan.dispatch inlines its traced body underneath
@functools.partial(
    jax.jit,
    static_argnames=("n_types", "cap", "engine", "cap_occ", "max_window",
                     "parallel_schedule", "block_next", "block_prev",
                     "window_tiles", "interpret"),
)
def count_batch(
    types: jax.Array,
    times: jax.Array,
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    *,
    n_types: int,
    cap: int,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
    block_next: Optional[int] = None,
    block_prev: Optional[int] = None,
    window_tiles: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Count a batch of same-length episodes over one stream (vmapped).

    Builds the per-type index then defers to :func:`count_batch_indexed`;
    jitted end-to-end so the index build fuses with the counting pass.
    """
    table, counts = events_lib.type_index(types, times, n_types, cap)
    return count_batch_indexed(
        table, counts, symbols, t_low, t_high, engine=engine,
        cap_occ=cap_occ, max_window=max_window,
        parallel_schedule=parallel_schedule, block_next=block_next,
        block_prev=block_prev, window_tiles=window_tiles, interpret=interpret)
