"""Non-overlapped episode counting — the paper's redesigned algorithm (§IV).

``count_nonoverlapped`` = parallel local tracking (subproblem 1) + greedy
overlap resolution (subproblem 2). Engines:

  engine="dense"                  beyond-paper optimized path (see tracking.py)
  engine="count_scan_write"       paper's preferred lock-free pipeline:
                                  backward tracking + count/scan/write
                                  compaction; output auto-sorted by end time
  engine="atomic_sort"            AtomicCompact analogue: forward tracking +
                                  count/scan/write offsets (TPU has no global
                                  atomics) + one final end-time sort
  engine="flags"                  CudppCompact analogue: flag-scan compaction
                                  over the expanded slot array

All engines return identical counts (property-tested against the numpy FSM
oracle) and differ only in cost profile, mirroring the paper's Fig 11/12
method comparison.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import events as events_lib
from . import scheduling, tracking
from .episodes import Episode

ENGINES = ("dense", "count_scan_write", "atomic_sort", "flags")


@dataclasses.dataclass
class CountResult:
    count: jax.Array        # i32 non-overlapped occurrence count
    n_superset: jax.Array   # i32 size of the tracked (overlapping) superset
    overflow: jax.Array     # bool static-capacity overflow indicator


def count_occurrences(
    times_by_sym: jax.Array,
    t_low: jax.Array,
    t_high: jax.Array,
    *,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
) -> CountResult:
    """Count on pre-gathered per-symbol time tables (jit/vmap-friendly core)."""
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}")
    cap = times_by_sym.shape[1]
    cap_occ = cap_occ or cap

    if engine == "dense":
        occ = tracking.track_dense(times_by_sym, t_low, t_high)
    elif engine == "count_scan_write":
        occ = tracking.track_faithful(
            times_by_sym, t_low, t_high, cap_occ=cap_occ,
            max_window=max_window, method="count_scan_write",
            direction="backward")
    elif engine == "atomic_sort":
        occ = tracking.track_faithful(
            times_by_sym, t_low, t_high, cap_occ=cap_occ,
            max_window=max_window, method="count_scan_write",
            direction="forward")
        occ = tracking.sort_by_end(occ)
    else:  # flags
        occ = tracking.track_faithful(
            times_by_sym, t_low, t_high, cap_occ=cap_occ,
            max_window=max_window, method="flags", direction="backward")

    count = scheduling.greedy_count(occ, parallel=parallel_schedule)
    return CountResult(count=count, n_superset=occ.n_superset, overflow=occ.overflow)


def count_nonoverlapped(
    stream: events_lib.EventStream,
    episode: Episode,
    *,
    engine: str = "dense",
    cap: Optional[int] = None,
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
) -> CountResult:
    """End-to-end count for one episode on one stream (public API)."""
    cap = cap or max(1, stream.n_events)
    table, counts = events_lib.type_index(
        stream.types, stream.times, stream.n_types, cap)
    sym, lo, hi = episode.as_arrays()
    times_by_sym, _ = events_lib.episode_symbol_times(table, counts, sym)
    res = count_occurrences(
        times_by_sym, lo, hi, engine=engine, cap_occ=cap_occ,
        max_window=max_window, parallel_schedule=parallel_schedule)
    per_type_overflow = jnp.any(counts > cap)
    return CountResult(res.count, res.n_superset, res.overflow | per_type_overflow)


@functools.partial(
    jax.jit,
    static_argnames=("n_types", "cap", "engine", "cap_occ", "max_window",
                     "parallel_schedule"),
)
def count_batch(
    types: jax.Array,
    times: jax.Array,
    symbols: jax.Array,     # i32[B, N]
    t_low: jax.Array,       # f32[B, N-1]
    t_high: jax.Array,      # f32[B, N-1]
    *,
    n_types: int,
    cap: int,
    engine: str = "dense",
    cap_occ: Optional[int] = None,
    max_window: int = 32,
    parallel_schedule: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Count a batch of same-length episodes over one stream (vmapped).

    The per-type index is built once and shared across the batch — the
    paper's pre-processing amortization. Returns (counts[B], n_superset[B],
    overflow[B]).
    """
    table, counts = events_lib.type_index(types, times, n_types, cap)

    def one(sym, lo, hi):
        tbs = table[sym]
        r = count_occurrences(
            tbs, lo, hi, engine=engine, cap_occ=cap_occ,
            max_window=max_window, parallel_schedule=parallel_schedule)
        return r.count, r.n_superset, r.overflow | jnp.any(counts > cap)

    return jax.vmap(one)(symbols, t_low, t_high)
