"""Train / prefill / serve step builders (the functions the dry-run lowers
and the launchers execute)."""
from __future__ import annotations

from typing import Callable

import jax

from ..models.model import Model
from ..optim.adamw import AdamW
from ..optim import compression


def make_train_step(model: Model, opt: AdamW, *,
                    compress: bool = False) -> Callable:
    """(params, opt_state, batch[, err_state, key]) -> updated state + metrics."""

    if not compress:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            params, opt_state, om = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **metrics, **om}
        return train_step

    def train_step_c(params, opt_state, batch, err_state, key):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        grads, err_state = compression.compress_grads(grads, err_state, key)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, err_state, {"loss": loss, **metrics, **om}
    return train_step_c


def make_prefill_step(model: Model) -> Callable:
    """Forward over the prompt; returns last-position logits (next-token
    distribution). Full-sequence logits are deliberately not materialized —
    the lm_head matmul runs on the final position only."""

    def prefill_step(params, batch):
        cfg = model.cfg
        x, positions = model._embed_inputs(params, batch)
        x = model.constrain(x, "hidden")
        from ..models import blocks, layers  # local to keep Model surface small
        x, _ = blocks.stack_apply(
            params["stack"], cfg, x, positions,
            constrain=model.constrain, remat="none", mesh=model.mesh)
        x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], x)
        else:
            logits = layers.dense(params["lm_head"], x)
        return logits[:, 0]

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One batched decode step: (params, cache, tokens, pos) ->
    (next-token logits, updated cache)."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step
