from . import steps
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["steps", "make_prefill_step", "make_serve_step", "make_train_step"]
