"""Render the dry-run JSON cells into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import glob
import json
import sys


def _fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:8.2f}"
    return f"{x:8.4f}"


def _load(out_dir: str):
    rows = []
    for p in sorted(glob.glob(f"{out_dir}/*/*.json")):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def roofline_table(out_dir: str = "experiments/dryrun",
                   mesh: str = "16x16") -> str:
    rows = [r for r in _load(out_dir) if r.get("mesh") == mesh]
    lines = [
        "| arch | shape | T_compute s | T_memory s | T_collective s | "
        "bottleneck | HLO GFLOPs/dev | coll GB/dev | MODEL/HLO | roofline frac | "
        "mem GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         "skipped (full attention @500k) | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | "
                         f"{r.get('error','')[:60]} | | | | | |")
            continue
        f = r["roofline"]
        mem = r["bytes_per_device_resident"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} |{_fmt_t(f['t_compute'])} |"
            f"{_fmt_t(f['t_memory'])} |{_fmt_t(f['t_collective'])} | "
            f"{f['bottleneck']} | {f['flops_per_device']/1e9:,.0f} | "
            f"{f['coll_bytes_per_device']/1e9:.2f} | "
            f"{f['useful_ratio']:.3f} | {f['peak_fraction']:.3f} | {mem:.1f} |")
    return "\n".join(lines)


def dryrun_table(out_dir: str = "experiments/dryrun") -> str:
    rows = _load(out_dir)
    lines = [
        "| mesh | arch | shape | status | compile s | args GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r["status"] != "ok":
            lines.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
                         f"{r['status']} | — | — | — |")
            continue
        ma = r["memory_analysis"]
        lines.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} | ok | "
            f"{r['compile_s']:.1f} | {ma['argument_size_in_bytes']/1e9:.2f} | "
            f"{ma['temp_size_in_bytes']/1e9:.2f} |")
    return "\n".join(lines)


def summary(out_dir: str = "experiments/dryrun") -> str:
    rows = _load(out_dir)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = len(rows) - n_ok - n_skip
    return (f"{len(rows)} cells: {n_ok} ok, {n_skip} skipped (documented), "
            f"{n_err} errors")


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    print(summary(out))
    print()
    print("## 16x16")
    print(roofline_table(out, "16x16"))
    print()
    print("## 2x16x16")
    print(roofline_table(out, "2x16x16"))
