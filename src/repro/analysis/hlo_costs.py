"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body exactly once
(verified empirically — see EXPERIMENTS.md §Roofline-notes), which silently
under-reports any scan-over-layers module by ~n_layers x. This walker
parses the optimized HLO, resolves operand shapes through a per-computation
symbol table, discovers each while's trip count from its condition
computation (scan conditions compare the induction variable against a
literal), and accumulates:

  * flops        — 2 * prod(result_dims) * contraction_size for every dot,
                   multiplied through nested while trip counts;
  * hbm_bytes    — per *kernel* (fusion = one kernel: operands + results;
                   fusion internals are free), a first-order HBM traffic
                   model;
  * coll_bytes   — operand bytes per collective kind (all-gather,
                   all-reduce, reduce-scatter, all-to-all,
                   collective-permute), trip-corrected;
  * op_mix       — instruction counts per opcode, trip-corrected (the
                   Table III "instructions executed" analogue).

All numbers are per-device (the module is the GSPMD-partitioned one).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "iota", "partition-id", "replica-id"}


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_types: list
    operand_names: list
    rest: str              # operand text + attributes (for dims / callees)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]

    def _update_shapes(self, instr: Instr):
        """Shapes of the 'update' operand (index 1) of a DUS/scatter."""
        if len(instr.operand_names) >= 2:
            src = self.by_name.get(instr.operand_names[1])
            if src is not None:
                return src.result_types
        return []


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, dict] = {}

    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and "{" in line and " = " not in line.split("{")[0]:
                name = hdr.group(2)
                cur = Computation(name, [], {})
                self.computations[name] = cur
                if hdr.group(1):
                    self.entry = name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            parsed = self._parse_instr(line)
            if parsed is None:
                continue
            cur.instrs.append(parsed)
            cur.by_name[parsed.name] = parsed

    @staticmethod
    def _parse_instr(line: str) -> Optional["Instr"]:
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        if not s.startswith("%"):
            return None
        eq = s.find(" = ")
        if eq < 0:
            return None
        name = s[1:eq]
        rest = s[eq + 3:]
        # type: either a parenthesized tuple (may contain /*index=N*/
        # comments) or a single dtype[shape]{layout} token
        if rest.startswith("("):
            depth, tend = 0, -1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        tend = i + 1
                        break
            if tend < 0:
                return None
        else:
            tend = rest.find(" ")
            if tend < 0:
                return None
        type_str = rest[:tend]
        after = rest[tend:].lstrip()
        m = _OP_RE.match(after)
        if not m:
            return None
        op = m.group(1)
        tail = after[m.end():]
        # operand region: up to the matching close paren at depth 0
        depth, end = 1, len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(tail[:end])
        return Instr(name, op, _parse_shapes(type_str), operands, tail)

    # ------------------------------------------------------------------
    def _operand_shapes(self, comp: Computation, instr: Instr):
        shapes = []
        for on in instr.operand_names:
            src = comp.by_name.get(on)
            if src is not None:
                shapes.extend(src.result_types)
        return shapes

    def _callee(self, instr: Instr, attr: str) -> Optional[str]:
        m = re.search(attr + r"=%?([\w.\-]+)", instr.rest)
        return m.group(1) if m else None

    def _trip_count(self, instr: Instr, cond_name: Optional[str]) -> int:
        # preferred: XLA records it on the while instruction
        m = re.search(r'known_trip_count[^0-9]*"n"\s*:\s*"?(\d+)', instr.rest)
        if m:
            return max(1, int(m.group(1)))
        comp = self.computations.get(cond_name or "")
        if comp is None:
            return 1
        # fallback: the loop bound is the s32 constant feeding the (possibly
        # fusion-wrapped) LT compare in the condition computation
        consts = [int(mm.group(1)) for ins in comp.instrs if ins.op == "constant"
                  for mm in [re.match(r"(-?\d+)", ins.rest)] if mm]
        return max(consts) if consts else 1

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        result_elems = 1
        for _, dims in instr.result_types:
            for d in dims:
                result_elems *= d
        # contraction size from lhs shape + lhs_contracting_dims
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
        lhs = comp.by_name.get(instr.operand_names[0]) if instr.operand_names else None
        contract = 1
        if m and lhs is not None and lhs.result_types:
            dims = lhs.result_types[0][1]
            for ax in m.group(1).split(","):
                if ax:
                    contract *= dims[int(ax)]
        return 2.0 * result_elems * contract

    def cost(self, comp_name: Optional[str] = None) -> dict:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.computations.get(comp_name)
        zero = {"flops": 0.0, "hbm_bytes": 0.0, "coll": {}, "op_mix": {}}
        if comp is None:
            return zero
        total = {"flops": 0.0, "hbm_bytes": 0.0, "coll": {}, "op_mix": {}}

        def add(dst, src, mult=1.0):
            dst["flops"] += src["flops"] * mult
            dst["hbm_bytes"] += src["hbm_bytes"] * mult
            for k, v in src["coll"].items():
                dst["coll"][k] = dst["coll"].get(k, 0.0) + v * mult
            for k, v in src["op_mix"].items():
                dst["op_mix"][k] = dst["op_mix"].get(k, 0.0) + v * mult

        self._memo[comp_name] = total  # break cycles defensively
        for ins in comp.instrs:
            mix_key = ins.op
            if ins.op in FREE_OPS:
                continue
            total["op_mix"][mix_key] = total["op_mix"].get(mix_key, 0.0) + 1
            if ins.op == "while":
                body = self._callee(ins, "body")
                cond = self._callee(ins, "condition")
                trip = self._trip_count(ins, cond)
                if body:
                    add(total, self.cost(body), trip)
                if cond:
                    add(total, self.cost(cond), trip)
                continue
            if ins.op in ("fusion", "call", "async-start"):
                callee = self._callee(ins, "calls") or self._callee(ins, "to_apply")
                inner = self.cost(callee) if callee else zero
                # fusion = one kernel: HBM = operands + results; inner dots count
                total["flops"] += inner["flops"]
                for k, v in inner["coll"].items():
                    total["coll"][k] = total["coll"].get(k, 0.0) + v
                op_shapes = self._operand_shapes(comp, ins)
                ob = _shape_bytes(op_shapes)
                rb = _shape_bytes(ins.result_types)
                called = self.computations.get(callee or "")
                if called is not None:
                    kinds = {i.op for i in called.instrs}
                    biggest = max((_shape_bytes([s]) for s in op_shapes),
                                  default=0)
                    if "dynamic-update-slice" in kinds:
                        # in-place slice-update fusion: the aliased buffer is
                        # not streamed; traffic ~ 2x the update regions
                        upd = sum(
                            _shape_bytes(called._update_shapes(i))
                            for i in called.instrs
                            if i.op == "dynamic-update-slice")
                        alias = biggest if rb == biggest else 0
                        total["hbm_bytes"] += (ob - biggest) + 2 * upd + (rb - alias)
                        continue
                    if kinds & {"dynamic-slice", "gather"}:
                        # slice-read fusion: the big source is not fully read
                        total["hbm_bytes"] += (ob - biggest) + 2 * rb
                        continue
                total["hbm_bytes"] += ob + rb
                continue
            if ins.op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      ins.rest)
                names = []
                for grp in branches:
                    for g in grp:
                        if g:
                            names.extend(re.findall(r"%?([\w.\-]+)", g))
                if names:
                    costs = [self.cost(n) for n in names]
                    best = max(costs, key=lambda c: c["flops"] + c["hbm_bytes"])
                    add(total, best)
                continue
            if ins.op == "dot":
                total["flops"] += self._dot_flops(comp, ins)
            if ins.op in ("dynamic-update-slice", "scatter"):
                # in-place slice update: traffic ~ 2x the update region, not
                # the whole buffer (XLA aliases input/output)
                upd = (self._operand_shapes(comp, ins) or [("f32", [0])])[1:]
                total["hbm_bytes"] += 2 * _shape_bytes(upd)
                continue
            if ins.op in ("dynamic-slice", "gather"):
                # reads only the slice region ~ result size
                total["hbm_bytes"] += 2 * _shape_bytes(ins.result_types)
                continue
            ob = _shape_bytes(self._operand_shapes(comp, ins))
            rb = _shape_bytes(ins.result_types)
            total["hbm_bytes"] += ob + rb
            for c in COLLECTIVES:
                if ins.op == c or ins.op == c + "-start":
                    total["coll"][c] = total["coll"].get(c, 0.0) + ob
                    break
        self._memo[comp_name] = total
        return total


def module_costs(hlo_text: str) -> dict:
    """Entry-point: trip-corrected per-device costs of an optimized module."""
    mod = HloModule(hlo_text)
    c = mod.cost()
    c["coll"]["total"] = float(sum(v for k, v in c["coll"].items()))
    return c


def top_contributors(hlo_text: str, n: int = 20, by: str = "hbm_bytes"):
    """Top-n individual instructions by trip-multiplied bytes (or flops).
    Diagnostic for the §Perf hypothesis loop."""
    mod = HloModule(hlo_text)
    items: list = []

    def walk(comp_name: str, mult: float, depth: int):
        comp = mod.computations.get(comp_name)
        if comp is None or depth > 12:
            return
        for ins in comp.instrs:
            if ins.op in FREE_OPS:
                continue
            if ins.op == "while":
                body = mod._callee(ins, "body")
                trip = mod._trip_count(ins, mod._callee(ins, "condition"))
                if body:
                    walk(body, mult * trip, depth + 1)
                continue
            if ins.op in ("fusion", "call"):
                callee = mod._callee(ins, "calls") or mod._callee(ins, "to_apply")
                inner = mod.cost(callee) if callee else {"flops": 0.0}
                op_shapes = mod._operand_shapes(comp, ins)
                ob = _shape_bytes(op_shapes)
                rb = _shape_bytes(ins.result_types)
                called = mod.computations.get(callee or "")
                label = f"{comp_name}/{ins.name} fusion"
                bytes_ = ob + rb
                if called is not None:
                    kinds = {i.op for i in called.instrs}
                    biggest = max((_shape_bytes([s]) for s in op_shapes), default=0)
                    if "dynamic-update-slice" in kinds:
                        upd = sum(_shape_bytes(called._update_shapes(i))
                                  for i in called.instrs
                                  if i.op == "dynamic-update-slice")
                        alias = biggest if rb == biggest else 0
                        bytes_ = (ob - biggest) + 2 * upd + (rb - alias)
                    elif kinds & {"dynamic-slice", "gather"}:
                        bytes_ = (ob - biggest) + 2 * rb
                items.append((bytes_ * mult, inner["flops"] * mult, label,
                              ins.result_types[:1]))
                continue
            if ins.op in ("dynamic-update-slice", "scatter"):
                b_ = 2 * _shape_bytes(comp._update_shapes(ins))
            elif ins.op in ("dynamic-slice", "gather"):
                b_ = 2 * _shape_bytes(ins.result_types)
            else:
                b_ = (_shape_bytes(mod._operand_shapes(comp, ins))
                      + _shape_bytes(ins.result_types))
            fl = mod._dot_flops(comp, ins) if ins.op == "dot" else 0.0
            items.append((b_ * mult, fl * mult,
                          f"{comp_name}/{ins.name} {ins.op}",
                          ins.result_types[:1]))

    walk(mod.entry, 1.0, 0)
    key = 0 if by == "hbm_bytes" else 1
    items.sort(key=lambda t: -t[key])
    return items[:n]
