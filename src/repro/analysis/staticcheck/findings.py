"""Finding records, inline suppression, and the checked-in baseline.

Shared plumbing for both staticcheck layers (DESIGN.md §13): the AST lint
rules (`astlint.py`) and the jaxpr/trace checks (`jaxpr_checks.py`) both
emit :class:`Finding`s; this module decides which of them count.

Suppression model — two mechanisms, used for two different things:

* **Inline suppression** (``# staticcheck: disable=REPRO003 -- reason``)
  marks an *individually sanctioned* site: the code is intentional, the
  justification rides next to it, and a reviewer sees both. Same-line, or
  a standalone comment on the line directly above for statements too long
  to share a line with their justification.

* **Baseline file** (``baseline.txt`` next to this module) exempts whole
  *files or trees* of seed scaffolding that the mining stack never calls
  (models/, optim/, ...). Entries are ``glob :: codes :: reason`` — codes
  are explicit, so the mechanical hygiene rules (REPRO006/REPRO007) keep
  running even on baselined files.

Everything else is an unsuppressed finding and exits the runner non-zero.
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Set, Tuple

__all__ = [
    "Finding", "RULES", "parse_suppressions", "Baseline", "load_baseline",
    "filter_findings", "format_findings", "BASELINE_PATH",
]

#: Rule registry: code -> one-line description (printed by ``--list-rules``
#: and embedded in reports). DESIGN.md §13 documents each at length.
RULES: Dict[str, str] = {
    "REPRO001": "falsy-or default on a capacity-like value (0 is valid; "
                "use `x if x is not None else default`)",
    "REPRO002": "interpret/tile knob accepted but never threaded to the "
                "next layer",
    "REPRO003": "direct jax.jit/pallas_call outside core/plan.py or "
                "kernels/ (bypasses the AOT executable cache)",
    "REPRO004": "device_get/block_until_ready inside a loop body (breaks "
                "the one-sync-per-level contract)",
    "REPRO005": "registry candidate never registered via plan.register_fn"
                "/tracking.register_engine",
    "REPRO006": "trailing whitespace",
    "REPRO007": "tab character in source",
    "REPRO101": "forbidden host-transfer/callback primitive in a traced "
                "plan body",
    "REPRO102": "plan shape or input spec not capacity-class-rounded",
    "REPRO103": "t_min seed restriction not applied exactly once per "
                "dispatch path",
    "REPRO104": "Pallas tile/grid/VMEM contract violation",
}


@dataclass(frozen=True, order=True)
class Finding:
    """One violation: ``path:line: code message`` (ruff-style)."""

    path: str       # repo-relative posix path, or plan://... for layer 1
    line: int       # 1-based; 0 for whole-plan findings
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


# ---------------------------------------------------------------------------
# Inline suppression comments
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*staticcheck:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s*(?:--|—).*)?$")


def parse_suppressions(source: str) -> Dict[int, Set[str]]:
    """Map line number -> suppressed codes for one file's text.

    A suppression on a code-bearing line covers that line; a suppression
    that IS the whole line (a standalone comment) covers the next
    non-comment line, so a multi-line justification block above a long
    statement still reaches the code it sanctions.
    """
    lines = source.splitlines()
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        codes = {c.strip().upper() for c in m.group(1).split(",") if c.strip()}
        out.setdefault(i, set()).update(codes)
        if line.lstrip().startswith("#"):
            j = i  # 0-based index of the line after the comment
            while j < len(lines) and lines[j].lstrip().startswith("#"):
                j += 1
            out.setdefault(j + 1, set()).update(codes)
    return out


def is_suppressed(finding: Finding,
                  suppressions: Dict[int, Set[str]]) -> bool:
    codes = suppressions.get(finding.line, set())
    return finding.code in codes or "ALL" in codes


# ---------------------------------------------------------------------------
# Baseline (file-level exemptions for seed scaffolding)
# ---------------------------------------------------------------------------

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.txt"


@dataclass(frozen=True)
class BaselineEntry:
    pattern: str          # fnmatch glob over repo-relative posix paths
    codes: Tuple[str, ...]  # ("*",) = every code
    reason: str

    def matches(self, path: str, code: str) -> bool:
        if "*" not in self.codes and code not in self.codes:
            return False
        if self.pattern.endswith("/"):
            return path.startswith(self.pattern)
        return path == self.pattern or fnmatch.fnmatch(path, self.pattern)


class Baseline:
    def __init__(self, entries: Sequence[BaselineEntry]):
        self.entries = list(entries)

    def exempts(self, finding: Finding) -> bool:
        return any(e.matches(finding.path, finding.code)
                   for e in self.entries)


def load_baseline(path: Path = BASELINE_PATH) -> Baseline:
    entries: List[BaselineEntry] = []
    if not path.exists():
        return Baseline(entries)
    for raw in path.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("::")]
        if len(parts) != 3:
            raise ValueError(f"malformed baseline entry: {raw!r} "
                             "(want 'glob :: codes :: reason')")
        pattern, codes_s, reason = parts
        codes = tuple(c.strip().upper() for c in codes_s.split(",")
                      if c.strip())
        for c in codes:
            if c != "*" and c not in RULES:
                raise ValueError(f"baseline names unknown rule {c!r}")
        entries.append(BaselineEntry(pattern, codes or ("*",), reason))
    return Baseline(entries)


# ---------------------------------------------------------------------------
# Filtering + report rendering
# ---------------------------------------------------------------------------


def filter_findings(
    findings: Iterable[Finding],
    *,
    sources: Dict[str, str],
    baseline: Baseline,
) -> Tuple[List[Finding], List[Finding]]:
    """Split into (unsuppressed, suppressed). ``sources`` maps the paths
    we have text for (lint layer) to their contents; plan:// findings have
    no text and can only be exempted by the baseline."""
    supp_by_path = {p: parse_suppressions(s) for p, s in sources.items()}
    kept: List[Finding] = []
    muted: List[Finding] = []
    for f in sorted(set(findings)):
        if baseline.exempts(f) or is_suppressed(
                f, supp_by_path.get(f.path, {})):
            muted.append(f)
        else:
            kept.append(f)
    return kept, muted


def format_findings(kept: Sequence[Finding],
                    muted: Sequence[Finding]) -> str:
    lines = [f.render() for f in kept]
    lines.append(f"staticcheck: {len(kept)} finding(s), "
                 f"{len(muted)} suppressed/baselined")
    return "\n".join(lines)
