"""Orchestration: discover files, run both layers, filter, report.

``scripts/staticcheck.py`` is a thin argparse shell around :func:`run`;
``benchmarks/run.py --only staticcheck`` and ``tests/test_staticcheck.py``
call it in-process.
"""
from __future__ import annotations

import json
import subprocess
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from . import astlint, jaxpr_checks
from .findings import (Baseline, Finding, filter_findings, format_findings,
                       load_baseline)

__all__ = ["discover_files", "changed_files", "run", "REPO_MARKERS",
           "TEXT_SUFFIXES"]

#: Non-python files the mechanical rules (REPRO006/REPRO007) also cover.
TEXT_SUFFIXES = (".py", ".yml", ".yaml", ".toml", ".json")

_SKIP_DIRS = {".git", "__pycache__", ".claude", ".pytest_cache",
              "node_modules", ".venv"}

REPO_MARKERS = ("pyproject.toml", ".git")


def repo_root(start: Optional[Path] = None) -> Path:
    p = (start or Path(__file__)).resolve()
    for parent in [p] + list(p.parents):
        if any((parent / m).exists() for m in REPO_MARKERS):
            return parent
    return Path.cwd()


def discover_files(root: Path) -> List[str]:
    out: List[str] = []
    for path in sorted(root.rglob("*")):
        if not path.is_file() or path.suffix not in TEXT_SUFFIXES:
            continue
        rel = path.relative_to(root).as_posix()
        if any(part in _SKIP_DIRS for part in path.parts):
            continue
        out.append(rel)
    return out


def changed_files(root: Path) -> List[str]:
    """Files touched vs HEAD (staged + unstaged + untracked)."""
    try:
        proc = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError):
        return discover_files(root)
    out = []
    for line in proc.stdout.splitlines():
        rel = line[3:].split(" -> ")[-1].strip().strip('"')
        if rel.endswith(TEXT_SUFFIXES) and (root / rel).is_file():
            out.append(rel)
    return sorted(set(out))


def run(
    *,
    root: Optional[Path] = None,
    files: Optional[Sequence[str]] = None,
    jaxpr: bool = True,
    matrix: str = "default",
    hlo: bool = False,
    baseline: Optional[Baseline] = None,
) -> Dict[str, object]:
    """Run staticcheck; returns a report dict (see keys below).

    ``files=None`` scans the whole tree. ``jaxpr=False`` skips layer 1
    (the ``--changed-only`` fast path). ``matrix`` is ``"default"`` or
    ``"full"``; ``hlo=True`` additionally compiles one representative
    plan and walks its optimized HLO.
    """
    root = root or repo_root()
    baseline = baseline if baseline is not None else load_baseline()
    files = discover_files(root) if files is None else list(files)

    findings: List[Finding] = []
    sources: Dict[str, str] = {}
    for rel in files:
        try:
            text = (root / rel).read_text()
        except (OSError, UnicodeDecodeError):
            continue
        sources[rel] = text
        if rel.endswith(".py"):
            findings.extend(astlint.lint_source(rel, text))
        else:
            findings.extend(astlint.lint_text(rel, text))

    n_plans = 0
    hlo_costs: Dict[str, float] = {}
    if jaxpr:
        plans = (jaxpr_checks.full_matrix() if matrix == "full"
                 else jaxpr_checks.default_matrix())
        n_plans = len(plans)
        findings.extend(jaxpr_checks.check_plans(plans))
        findings.extend(jaxpr_checks.check_tuned_table())
        if hlo and plans:
            hlo_findings, hlo_costs = jaxpr_checks.check_hlo(plans[0])
            findings.extend(hlo_findings)

    kept, muted = filter_findings(findings, sources=sources,
                                  baseline=baseline)
    return {
        "findings": kept,
        "suppressed": muted,
        "files_checked": len(sources),
        "plans_checked": n_plans,
        "matrix": matrix if jaxpr else "skipped",
        "hlo_costs": hlo_costs,
        "text": format_findings(kept, muted),
        "ok": not kept,
    }


def report_json(report: Dict[str, object]) -> str:
    def enc(f: Finding):
        return {"path": f.path, "line": f.line, "code": f.code,
                "message": f.message}
    return json.dumps({
        "ok": report["ok"],
        "files_checked": report["files_checked"],
        "plans_checked": report["plans_checked"],
        "matrix": report["matrix"],
        "hlo_costs": report["hlo_costs"],
        "findings": [enc(f) for f in report["findings"]],
        "suppressed": [enc(f) for f in report["suppressed"]],
    }, indent=2)
