"""repro.analysis.staticcheck — static enforcement of the miner's
sync, recompile, and kernel contracts (DESIGN.md §13).

Two layers:

* :mod:`.astlint` — stdlib-``ast`` lint rules REPRO001–REPRO007 over the
  source tree (the bug classes PR 5/6/7 fixed by hand, kept fixed).
* :mod:`.jaxpr_checks` — trace-level checks REPRO101–REPRO104 over every
  registered counting fn × engine: callback-free jaxprs, capacity-class
  rounding, t_min-once, and Pallas tile/grid/VMEM contracts.

Run via ``scripts/staticcheck.py`` (``--all`` | ``--changed-only`` |
``--full-matrix``); CI runs it blocking on every push.
"""
from .findings import (Baseline, Finding, RULES, filter_findings,
                       format_findings, load_baseline, parse_suppressions)
from . import astlint, jaxpr_checks
from .runner import changed_files, discover_files, report_json, run

__all__ = [
    "Baseline", "Finding", "RULES", "astlint", "jaxpr_checks",
    "changed_files", "discover_files", "filter_findings",
    "format_findings", "load_baseline", "parse_suppressions",
    "report_json", "run",
]
