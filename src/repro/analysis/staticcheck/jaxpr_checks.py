"""Layer 1 — jaxpr/trace checks over the registered plan matrix.

For every registered counting fn × registered engine (× a shape matrix),
the plan builder is traced with :func:`jax.make_jaxpr` and the resulting
jaxpr is walked recursively (scan/while/cond/pjit/pallas sub-jaxprs
included) to enforce four contracts the repo otherwise only learns about
dynamically:

* **REPRO101** — no host-transfer/callback primitive anywhere in the
  traced body. The miner's level loop performs exactly ONE host sync per
  level (PR 1/6); a callback inside a traced counting body would add a
  hidden one per launch.
* **REPRO102** — every plan shape field and every input-spec dimension is
  a fixed point of :func:`plan.capacity_class`/:func:`plan.pow2_ceil`
  (or a plan-derived semantic size). This is the O(#buckets) compile
  contract (PR 7): a non-class-rounded shape entering ``dispatch()``
  mints unbounded cache keys.
* **REPRO103** — ``tracking.restrict_seed_row`` runs exactly once for
  plans that carry a ``t_min`` (``count_tail``) and never otherwise: the
  PR 6 double-apply hazard, counted by instrumenting the function during
  tracing.
* **REPRO104** — Pallas tile contracts hold statically for the plan's
  resolved tiles and for every ``tuned_configs.json`` entry: the lcm-
  padded capacity is covered exactly by the grid, tiles divide it, the
  scalar-prefetched index map stays in bounds, and an analytic per-grid-
  step VMEM estimate stays under the 16 MiB/core budget (the estimate is
  also cross-checked against ``analysis.roofline`` byte accounting).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from .findings import Finding

__all__ = [
    "check_plan", "check_plans", "check_tuned_table", "check_hlo",
    "default_matrix", "full_matrix", "FORBIDDEN_PRIMITIVES",
    "VMEM_BUDGET_BYTES", "estimate_vmem_bytes", "plan_path",
]

#: Primitive names that imply a host round-trip inside a traced body.
FORBIDDEN_PRIMITIVES = frozenset({
    "outside_call", "host_callback", "infeed", "outfeed", "device_put",
    "debug_print",
})

#: Fns whose EngineConfig legitimately carries a t_min (applied exactly
#: once by `consume_seed_restriction` at the dispatch altitude). All other
#: fns must never touch the seed row.
EXPECTED_TMIN_APPLICATIONS = {"count_tail": 1}

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # ~16 MB/core (TPU v4/v5e class)


def plan_path(plan) -> str:
    """Stable pseudo-path for plan-level findings (baseline-matchable)."""
    return (f"plan://{plan.fn}/{plan.engine}/L{plan.level}"
            f"N{plan.cap}B{plan.batch}S{plan.streams}T{plan.tail_cap}")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _subjaxprs(params: dict):
    for v in params.values():
        stack = [v]
        while stack:
            item = stack.pop()
            if isinstance(item, (tuple, list)):
                stack.extend(item)
            elif isinstance(item, jax.core.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jax.core.Jaxpr):
                yield item


def iter_primitives(jaxpr) -> Iterable[Tuple[str, dict]]:
    """(primitive_name, params) for every eqn, recursing into sub-jaxprs
    (scan/while/cond bodies, nested pjit, pallas kernel jaxprs)."""
    seen = set()
    stack = [jaxpr]
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        for eqn in jx.eqns:
            yield eqn.primitive.name, eqn.params
            stack.extend(_subjaxprs(eqn.params))


def _is_forbidden(prim_name: str) -> bool:
    return prim_name in FORBIDDEN_PRIMITIVES or "callback" in prim_name


# ---------------------------------------------------------------------------
# tracing with t_min instrumentation
# ---------------------------------------------------------------------------


@contextmanager
def _count_seed_restrictions():
    """Count tracking.restrict_seed_row calls made while tracing."""
    from ...core import tracking
    counter = {"n": 0}
    original = tracking.restrict_seed_row

    def counting(times_by_sym, t_min):
        counter["n"] += 1
        return original(times_by_sym, t_min)

    tracking.restrict_seed_row = counting
    try:
        yield counter
    finally:
        tracking.restrict_seed_row = original


def trace_plan(plan) -> Tuple[object, int]:
    """(closed_jaxpr, n_seed_restrictions) for one plan's traced body."""
    from ...core import plan as plan_mod
    entry = plan_mod._fn_entry(plan.fn)
    fn = entry.build(plan)
    specs = entry.specs(plan)
    with _count_seed_restrictions() as counter:
        closed = jax.make_jaxpr(fn)(*specs)
    return closed, counter["n"]


# ---------------------------------------------------------------------------
# individual checks
# ---------------------------------------------------------------------------


def check_callbacks(plan, closed) -> List[Finding]:
    path = plan_path(plan)
    out = []
    for name, _params in iter_primitives(closed.jaxpr):
        if _is_forbidden(name):
            out.append(Finding(
                path, 0, "REPRO101",
                f"forbidden primitive `{name}` in traced body of "
                f"{plan.fn!r} (engine {plan.engine!r}) — hidden host sync"))
    return out


def check_rounding(plan, specs) -> List[Finding]:
    from ...core.plan import capacity_class, pow2_ceil
    path = plan_path(plan)
    out = []
    if plan.cap != capacity_class(plan.cap):
        out.append(Finding(path, 0, "REPRO102",
                           f"plan.cap={plan.cap} is not a capacity class "
                           f"(expected {capacity_class(plan.cap)})"))
    if plan.batch != pow2_ceil(plan.batch):
        out.append(Finding(path, 0, "REPRO102",
                           f"plan.batch={plan.batch} is not pow2-rounded "
                           f"(expected {pow2_ceil(plan.batch)})"))
    if plan.streams and plan.streams != pow2_ceil(plan.streams):
        out.append(Finding(path, 0, "REPRO102",
                           f"plan.streams={plan.streams} is not "
                           "pow2-rounded"))
    # every spec dim must be plan-derived: the bucket axes (already checked
    # above) or a semantic size the bucket carries. Anything else is a
    # shape that will mint fresh cache keys per call site.
    allowed = {plan.cap, plan.batch, plan.streams, plan.level,
               plan.level - 1, plan.n_types, plan.tail_cap, 1}
    for i, spec in enumerate(specs):
        for d in spec.shape:
            if d not in allowed:
                out.append(Finding(
                    path, 0, "REPRO102",
                    f"spec[{i}] dim {d} (shape {tuple(spec.shape)}) is "
                    "not derived from the plan bucket"))
    return out


def check_tmin(plan, n_restrictions: int) -> List[Finding]:
    expected = EXPECTED_TMIN_APPLICATIONS.get(plan.fn, 0)
    if n_restrictions == expected:
        return []
    return [Finding(
        plan_path(plan), 0, "REPRO103",
        f"restrict_seed_row ran {n_restrictions}x while tracing "
        f"{plan.fn!r} (expected {expected}x) — t_min must be consumed "
        "exactly once per dispatch path")]


# -- Pallas tile/grid/VMEM contracts ----------------------------------------


def estimate_vmem_bytes(kind: str, levels: int, pcap: int, bn: int,
                        bp: int, chunk: int) -> int:
    """Analytic per-grid-step VMEM footprint of the two kernel families.

    Conservative: operand/output blocks at 4 B/elem plus the dominant
    in-kernel intermediates (the [.., BN, BP] window compare at 5 B/elem
    for bool+f32 operands, gathers and compaction arrays at 4 B/elem).
    The track estimate matches the documented BN + 2*BP + BN*BP shape in
    kernels/episode_track.py; the count estimate covers a whole R-row
    chunk across all levels (times block is [R, N, pcap]).
    """
    nt = max(1, pcap // bn)
    if kind == "track":
        # blocks: t_next bn, t_prev pcap, scratch (2, pcap), out bn + 1
        blocks = 4 * (2 * bn + 3 * pcap + 1)
        inter = 5 * bn * bp            # ok/where compare [BN, BP]
        return blocks + inter
    if kind == "count":
        r = max(1, chunk)
        n = levels + 1
        blocks = 4 * r * (n * pcap + 2 * levels + 2 * levels * nt + 3)
        compare = 5 * r * nt * bn * bp   # [R, NT, BN, BP] == [R, pcap, BP]
        gathers = 2 * 4 * r * pcap       # tp/vp tile gathers
        compact = 4 * 4 * r * pcap       # csum/src/sT/eT
        return blocks + compare + gathers + compact
    raise ValueError(f"unknown kernel kind {kind!r}")


def _tile_contract(path: str, kind: str, levels: int, cap: int,
                   batch_rows: int, bn: int, bp: int, wt: int,
                   chunk: int) -> List[Finding]:
    from ...kernels import ops
    out: List[Finding] = []
    # kernels clamp tiles to the (padded) capacity before the divisibility
    # check, exactly as track_*_pallas do
    ebn, ebp, pcap = ops.tile_geometry(cap, bn, bp)
    ebn = min(ebn, pcap)
    ebp = min(ebp, pcap)
    if pcap < cap:
        out.append(Finding(path, 0, "REPRO104",
                           f"padded cap {pcap} < cap {cap}"))
    if pcap % ebn or pcap % ebp:
        out.append(Finding(
            path, 0, "REPRO104",
            f"tiles ({ebn},{ebp}) do not divide padded cap {pcap} — "
            "pallas_call would raise at launch"))
        return out
    next_tiles = pcap // ebn
    prev_tiles = pcap // ebp
    if next_tiles * ebn != pcap:
        out.append(Finding(path, 0, "REPRO104",
                           f"grid {next_tiles}x{ebn} != padded cap {pcap} "
                           "(inexact coverage)"))
    # index-map bound: start_tile is clipped to [0, prev_tiles - wt_eff],
    # so st[i] + j <= prev_tiles - 1 must hold for all j < wt_eff
    wt_eff = prev_tiles if wt == 0 else min(wt, prev_tiles)
    max_start = max(prev_tiles - wt_eff, 0)
    if max_start + wt_eff > prev_tiles:
        out.append(Finding(path, 0, "REPRO104",
                           f"index map out of bounds: start {max_start} + "
                           f"window {wt_eff} > prev tiles {prev_tiles}"))
    vmem = estimate_vmem_bytes(kind, max(1, levels), pcap, ebn, ebp, chunk)
    if vmem > VMEM_BUDGET_BYTES:
        out.append(Finding(
            path, 0, "REPRO104",
            f"estimated VMEM {vmem / 2**20:.2f} MiB per grid step exceeds "
            f"the {VMEM_BUDGET_BYTES // 2**20} MiB budget "
            f"(kind={kind}, pcap={pcap}, bn={ebn}, bp={ebp}, "
            f"chunk={chunk})"))
    return out


def check_plan_tiles(plan) -> List[Finding]:
    if plan.tile_cap < 1:
        return []  # malformed plans are uncacheable_reason'd, not tiled
    return _tile_contract(
        plan_path(plan), plan.kind, plan.level - 1, plan.tile_cap,
        max(plan.streams, 1) * plan.batch, plan.block_next,
        plan.block_prev, plan.window_tiles, plan.chunk)


_KEY_RE = re.compile(r"^(count|track):L(\d+):N(\d+):B(\d+)$")


def check_tuned_table(path: Optional[str] = None) -> List[Finding]:
    """Static contract check of every tuned_configs.json entry."""
    from ...kernels import autotune
    table = autotune.load_table(path)
    out: List[Finding] = []
    src = "src/repro/kernels/tuned_configs.json"
    for key, cfg in sorted(table.items()):
        m = _KEY_RE.match(key)
        if not m:
            out.append(Finding(src, 0, "REPRO104",
                               f"malformed bucket key {key!r}"))
            continue
        kind, levels, cap, batch = (m.group(1), int(m.group(2)),
                                    int(m.group(3)), int(m.group(4)))
        resolved = autotune.resolve(kind, levels, cap, batch)
        out.extend(_tile_contract(
            f"{src}#{key}", kind, levels, cap, batch,
            resolved.block_next, resolved.block_prev,
            resolved.window_tiles, resolved.chunk))
    return out


# ---------------------------------------------------------------------------
# HLO-level spot check (compiled module, reuses analysis.hlo_costs)
# ---------------------------------------------------------------------------


def check_hlo(plan) -> Tuple[List[Finding], Dict[str, float]]:
    """Compile one plan and walk the optimized HLO: no host custom-calls,
    plus the hlo_costs byte/flop accounting for the report."""
    from ...core import plan as plan_mod
    from .. import hlo_costs
    entry = plan_mod._fn_entry(plan.fn)
    # staticcheck: disable=REPRO003 -- the checker compiles one plan
    # off-cache on purpose to inspect its optimized HLO
    compiled = jax.jit(entry.build(plan)).lower(*entry.specs(plan)).compile()
    text = compiled.as_text()
    out: List[Finding] = []
    path = plan_path(plan)
    for i, line in enumerate(text.splitlines(), start=1):
        if "custom-call" in line and ("callback" in line
                                      or "host" in line.lower()):
            out.append(Finding(path, i, "REPRO101",
                               "host/callback custom-call in compiled HLO"))
    try:
        costs = hlo_costs.module_costs(text)
    except Exception:  # parser is best-effort across jax/XLA versions
        costs = {}
    return out, costs


# ---------------------------------------------------------------------------
# plan matrices + the combined per-plan entry point
# ---------------------------------------------------------------------------

_CORPUS_FNS = ("count_corpus", "count_corpus_tail",
               "count_corpus_tail_grouped")
_TAIL_FNS = ("count_tail", "count_corpus_tail", "count_corpus_tail_grouped")


def _plan(fn: str, engine: str, *, level: int = 3, cap: int = 256,
          batch: int = 8, streams: int = 4, tail_cap: int = 64):
    from ...core import plan as plan_mod
    return plan_mod.plan_for(
        fn, level=level, n_types=8, cap=cap, batch=batch,
        streams=streams if fn in _CORPUS_FNS else 0,
        tail_cap=tail_cap if fn in _TAIL_FNS else 0,
        engine=engine, interpret=True)


def _registered_fns() -> Sequence[str]:
    from ...core import plan as plan_mod
    plan_mod._fn_entry("count_indexed")  # import counting -> register all
    return tuple(sorted(plan_mod._FNS))


def default_matrix() -> List:
    """Every fn × every engine at one representative bucket (CI tier)."""
    from ...core import tracking
    return [_plan(fn, eng)
            for fn in _registered_fns() for eng in tracking.engine_names()]


def full_matrix() -> List:
    """default_matrix + shape sweep on the two dense engines (nightly)."""
    from ...core import tracking
    plans = default_matrix()
    sweep_engines = [e for e in ("dense", "dense_pallas_fused")
                     if e in tracking.engine_names()]
    for fn in _registered_fns():
        for eng in sweep_engines:
            for level in (2, 4):
                for cap in (256, 1024):
                    for batch in (8, 32):
                        plans.append(_plan(fn, eng, level=level, cap=cap,
                                           batch=batch))
    return plans


def check_plan(plan) -> List[Finding]:
    """All layer-1 checks for one plan."""
    from ...core import plan as plan_mod
    entry = plan_mod._fn_entry(plan.fn)
    out = check_rounding(plan, entry.specs(plan))
    out.extend(check_plan_tiles(plan))
    try:
        closed, n_restrict = trace_plan(plan)
    except Exception as err:
        out.append(Finding(plan_path(plan), 0, "REPRO101",
                           f"plan builder failed to trace: {err}"))
        return out
    out.extend(check_callbacks(plan, closed))
    out.extend(check_tmin(plan, n_restrict))
    return out


def check_plans(plans: Iterable) -> List[Finding]:
    out: List[Finding] = []
    for p in plans:
        out.extend(check_plan(p))
    return out
