"""Layer 2 — AST lint rules (stdlib ``ast`` only, no new deps).

Each rule encodes a bug class this repo has actually shipped and fixed:

* REPRO001 — ``cap or default``: PR 5 swept a whole family of falsy-`or`
  defaults where ``cap=0`` / ``cap_occ=0`` are *valid* values that the
  ``or`` silently replaced. Capacity-like names must default via
  ``is None``.
* REPRO002 — a function accepts an ``interpret``/tile knob but never
  reads it: the knob dies there instead of reaching the kernel layer
  (the PR 6 tile-threading hazard).
* REPRO003 — direct ``jax.jit``/``pl.pallas_call`` outside
  ``core/plan.py``/``kernels/``: recompiles per call site and bypasses
  the PR 7 AOT executable cache. Sanctioned escape hatches carry inline
  suppressions, so every bypass is enumerable by grepping the code.
* REPRO004 — ``device_get``/``block_until_ready`` inside a loop body:
  the PR 1/6 one-sync-per-level contract. The four sanctioned per-level
  sync points are inline-suppressed — the suppressions ARE the list of
  allowed syncs.
* REPRO005 — an ``*Engine`` class or ``_build_*``/``_specs_*`` builder
  in a registering module that never reaches
  ``register_engine``/``register_fn``: dead registry candidates are
  invisible to the warm()/staticcheck plan matrices.
* REPRO006/REPRO007 — trailing whitespace / tabs: the two mechanical
  rules the advisory ruff-format gate cannot enforce in this container
  (no ruff, no network — see ci.yml), kept blocking here instead.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

from .findings import Finding

__all__ = ["lint_source", "lint_text", "lint_file", "CAPACITY_NAMES",
           "KNOB_NAMES", "SPINE_ALLOWED"]

#: Names whose value 0 is semantically valid, so `x or d` is a bug.
CAPACITY_NAMES: Set[str] = {
    "cap", "capacity", "cap_occ", "tail_cap", "tile_cap", "cap_rows",
    "max_window", "window_tiles", "block_next", "block_prev", "chunk",
    "streams", "batch", "n_events", "max_candidates",
}

#: Knob params that exist only to be forwarded to the next layer.
KNOB_NAMES: Set[str] = {
    "interpret", "block_next", "block_prev", "window_tiles", "chunk",
}

#: Paths allowed to call jax.jit / pallas_call directly (REPRO003): the
#: dispatch spine itself and the kernel layer it compiles.
SPINE_ALLOWED = ("src/repro/core/plan.py", "src/repro/kernels/")


def _is_capacity_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    else:
        return None
    if (name in CAPACITY_NAMES or name.endswith("_cap")
            or name.startswith("cap_")):
        return name
    return None


def _dotted(node: ast.AST) -> str:
    """'jax.experimental.pallas.pallas_call' for an attribute chain, or ''."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jax_jit(node: ast.AST) -> bool:
    d = _dotted(node)
    return d == "jax.jit" or d.endswith(".jax.jit") or d == "jit"


def _is_pallas_call(node: ast.AST) -> bool:
    d = _dotted(node)
    return d.split(".")[-1] == "pallas_call" if d else False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._loop_depth = 0
        self._condition_tests: Set[int] = set()
        # module-level registry bookkeeping for REPRO005
        self.registered_names: Set[str] = set()
        self.has_register_fn = False
        self.has_register_engine = False
        self.module_defs: List[ast.FunctionDef] = []
        self.module_classes: List[ast.ClassDef] = []

    def _flag(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(
            Finding(self.path, getattr(node, "lineno", 0), code, message))

    # -- REPRO001 ----------------------------------------------------------
    def _note_condition(self, test: ast.AST) -> None:
        # `if cap or default:` is a truthiness *test*, not a default —
        # only value-position BoolOps are the PR 5 bug shape.
        self._condition_tests.add(id(test))

    def visit_If(self, node: ast.If) -> None:
        self._note_condition(node.test)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._note_condition(node.test)
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._note_condition(node.test)
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._note_condition(node.test)
        self.generic_visit(node)

    def visit_BoolOp(self, node: ast.BoolOp) -> None:
        if (isinstance(node.op, ast.Or) and id(node) not in
                self._condition_tests):
            name = _is_capacity_name(node.values[0])
            if name is not None:
                self._flag(node, "REPRO001",
                           f"`{name} or ...` treats {name}=0 as unset; "
                           f"use `{name} if {name} is not None else ...`")
        self.generic_visit(node)

    # -- REPRO002 ----------------------------------------------------------
    def _check_knobs(self, node) -> None:
        args = node.args
        params = (args.posonlyargs + args.args + args.kwonlyargs)
        knob_params = [a.arg for a in params
                       if a.arg in KNOB_NAMES and not a.arg.startswith("_")]
        if not knob_params:
            return
        body = node.body
        if body and isinstance(body[0], ast.Expr) and isinstance(
                body[0].value, ast.Constant):
            body = body[1:]  # skip docstring
        # trivial bodies (Protocol stubs, NotImplementedError shells) are
        # declarations, not plumbing — nothing to thread.
        if all(isinstance(s, (ast.Pass, ast.Raise)) or
               (isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant))
               for s in body):
            return
        loaded: Set[str] = set()
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Name) and isinstance(
                        sub.ctx, ast.Load):
                    loaded.add(sub.id)
        for knob in knob_params:
            if knob not in loaded:
                self._flag(node, "REPRO002",
                           f"knob parameter `{knob}` accepted by "
                           f"`{node.name}` but never used/threaded")

    def _check_decorators(self, node) -> None:
        # bare `@jax.jit` (an Attribute, not a Call) never reaches
        # visit_Call — check decorator lists explicitly
        if self.path.startswith(SPINE_ALLOWED):
            return
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _is_jax_jit(target) and not isinstance(dec, ast.Call):
                self.findings.append(Finding(
                    self.path, dec.lineno, "REPRO003",
                    "@jax.jit decorator outside plan.py/kernels/ bypasses "
                    "the AOT executable cache; route through "
                    "plan.dispatch/register_fn"))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_knobs(node)
        self._check_decorators(node)
        # loops don't cross a function boundary: a closure defined inside a
        # loop body is not itself "in" the loop for sync accounting.
        outer = self._loop_depth
        self._loop_depth = 0
        self.generic_visit(node)
        self._loop_depth = outer

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_knobs(node)
        self._check_decorators(node)
        outer = self._loop_depth
        self._loop_depth = 0
        self.generic_visit(node)
        self._loop_depth = outer

    # -- REPRO003 / REPRO004 ----------------------------------------------
    def visit_For(self, node: ast.For) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        in_spine = self.path.startswith(SPINE_ALLOWED)
        if not in_spine:
            if _is_jax_jit(func):
                self._flag(node, "REPRO003",
                           "direct jax.jit outside plan.py/kernels/ "
                           "bypasses the AOT executable cache; route "
                           "through plan.dispatch/register_fn")
            elif _is_pallas_call(func):
                self._flag(node, "REPRO003",
                           "direct pallas_call outside kernels/; kernels "
                           "are launched via the kernel layer only")
            elif (_dotted(func).endswith("functools.partial")
                  or _dotted(func) == "partial") and node.args:
                if _is_jax_jit(node.args[0]):
                    self._flag(node, "REPRO003",
                               "functools.partial(jax.jit, ...) outside "
                               "plan.py/kernels/ bypasses the AOT "
                               "executable cache")
        if self._loop_depth > 0:
            d = _dotted(func)
            tail = d.split(".")[-1] if d else ""
            if tail in ("device_get", "block_until_ready"):
                self._flag(node, "REPRO004",
                           f"`{tail}` inside a loop body — the level loop "
                           "allows ONE sanctioned sync per level; suppress "
                           "inline if this is it")
        # registry bookkeeping (REPRO005)
        d = _dotted(func)
        tail = d.split(".")[-1] if d else ""
        if tail == "register_fn":
            self.has_register_fn = True
            for a in node.args + [kw.value for kw in node.keywords]:
                for sub in ast.walk(a):
                    if isinstance(sub, ast.Name):
                        self.registered_names.add(sub.id)
        elif tail == "register_engine":
            self.has_register_engine = True
            for a in node.args + [kw.value for kw in node.keywords]:
                for sub in ast.walk(a):
                    if isinstance(sub, ast.Name):
                        self.registered_names.add(sub.id)
                    elif isinstance(sub, ast.Attribute):
                        self.registered_names.add(sub.attr)
        self.generic_visit(node)

    # -- REPRO005 bookkeeping ---------------------------------------------
    def visit_Module(self, node: ast.Module) -> None:
        for stmt in node.body:
            if isinstance(stmt, ast.FunctionDef):
                self.module_defs.append(stmt)
            elif isinstance(stmt, ast.ClassDef):
                self.module_classes.append(stmt)
        self.generic_visit(node)

    def finish(self) -> None:
        """Module-level REPRO005: only meaningful in modules that register
        at least one candidate (others may define unrelated helpers)."""
        if self.has_register_fn:
            for fn in self.module_defs:
                if (fn.name.startswith(("_build_", "_specs_"))
                        and fn.name not in self.registered_names):
                    self._flag(fn, "REPRO005",
                               f"builder `{fn.name}` defined but never "
                               "passed to plan.register_fn")
        if self.has_register_engine:
            for cls in self.module_classes:
                if not cls.name.endswith("Engine"):
                    continue
                bases = {_dotted(b).split(".")[-1] for b in cls.bases}
                if "Protocol" in bases:
                    continue  # interface definition, not a candidate
                if cls.name not in self.registered_names:
                    self._flag(cls, "REPRO005",
                               f"engine class `{cls.name}` defined but "
                               "never passed to tracking.register_engine")


def lint_text(path: str, source: str) -> List[Finding]:
    """REPRO006/REPRO007 — run on any text file, python or not."""
    out: List[Finding] = []
    for i, line in enumerate(source.splitlines(), start=1):
        if line != line.rstrip(" \t"):
            out.append(Finding(path, i, "REPRO006", "trailing whitespace"))
        if "\t" in line:
            out.append(Finding(path, i, "REPRO007", "tab character"))
    return out


def lint_source(path: str, source: str) -> List[Finding]:
    """All AST rules + text rules for one python file's contents."""
    out = lint_text(path, source)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        out.append(Finding(path, err.lineno or 0, "REPRO005",
                           f"unparseable python: {err.msg}"))
        return out
    v = _Visitor(path)
    v.visit(tree)
    v.finish()
    return out + v.findings


def lint_file(repo_root: Path, rel_path: str) -> List[Finding]:
    text = (repo_root / rel_path).read_text()
    if rel_path.endswith(".py"):
        return lint_source(rel_path, text)
    return lint_text(rel_path, text)
