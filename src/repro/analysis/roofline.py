"""Three-term roofline model from compiled dry-run artifacts.

    T_compute    = FLOPs_total   / (chips * PEAK_FLOPS)
    T_memory     = HBM_bytes     / (chips * HBM_BW)
    T_collective = coll_bytes    / (chips * ICI_BW)

`cost_analysis()` on a GSPMD-partitioned module is **per-device** (verified
by calibration in EXPERIMENTS.md §Roofline-notes: a 4.4 TFLOP global matmul
on 512 devices reports 8.6 GFLOP), so per-device numbers are used directly
against per-chip peaks; *_total in the report = per_device * chips.

collective_bytes is not in cost_analysis: we parse the optimized HLO and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (sync and async -start forms).

This model is LOAD-BEARING for the hot path, not just reporting:
``kernels.autotune.model_time`` routes its analytic per-launch cost dicts
through :func:`analyze` to pre-rank tile candidates, and the winners land
in ``kernels/tuned_configs.json`` — the table every counting entry point
resolves ``None`` block knobs against (``benchmarks/run.py --autotune``
regenerates it). Changing the peaks or the t_compute/t_memory terms here
reshapes the candidate ranking, so recheck the tuned table after edits.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"f8e4m3fn|f8e5m2|bf16|f16|f32|f64|c64|c128)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind operand bytes of every collective in an HLO module."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand shapes: every dtype[shape] token AFTER the op name
        tail = line[m.end():]
        # stop at metadata junk: operands live before `)` + attributes;
        # attribute regions (replica_groups etc.) contain no dtype[...] tokens
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tail))
        out[kind] = out.get(kind, 0.0) + float(total)
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6*N*D (active) per step, global
    useful_ratio: float           # model_flops / (flops_per_device*chips)
    peak_fraction: float          # t_compute / max(t_*) — roofline fraction
    argument_bytes: int = 0
    temp_bytes: int = 0
    output_bytes: int = 0

    def asdict(self):
        return dataclasses.asdict(self)


def analyze(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: Dict[str, float], coll: Dict[str, float],
    model_flops: float, memstats: Optional[dict] = None,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    mem_bytes = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0.0))
    t_c = flops / PEAK_FLOPS            # per-device flops / per-chip peak
    t_m = mem_bytes / HBM_BW
    t_x = cb / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    t_max = max(t_c, t_m, t_x, 1e-30)
    global_flops = flops * chips
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=mem_bytes,
        coll_bytes_per_device=cb,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        peak_fraction=t_c / t_max,
    )
    if memstats:
        r.argument_bytes = int(memstats.get("argument_size_in_bytes", 0))
        r.temp_bytes = int(memstats.get("temp_size_in_bytes", 0))
        r.output_bytes = int(memstats.get("output_size_in_bytes", 0))
    return r


def model_flops_for(cfg, shape, n_active: int) -> float:
    """6*N_active*D per optimizer step (train) or per token batch (serve)."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens       # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token per seq


def load_reports(path_glob: str):
    import glob
    rows = []
    for p in sorted(glob.glob(path_glob)):
        with open(p) as f:
            rows.append(json.load(f))
    return rows
