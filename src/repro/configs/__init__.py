"""Architecture registry: the 10 assigned archs + the paper's mining config."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ArchConfig, MoECfg, n_active_params, _n_params
from .shapes import SHAPES, ShapeSpec, applicable, input_specs

from . import (
    dbrx_132b, deepseek_moe_16b, musicgen_large, stablelm_1_6b, granite_3_2b,
    command_r_plus_104b, qwen3_0_6b, pixtral_12b, recurrentgemma_2b, rwkv6_3b,
)

_MODULES = [
    dbrx_132b, deepseek_moe_16b, musicgen_large, stablelm_1_6b, granite_3_2b,
    command_r_plus_104b, qwen3_0_6b, pixtral_12b, recurrentgemma_2b, rwkv6_3b,
]

REGISTRY: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def list_archs() -> List[str]:
    return sorted(REGISTRY)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return REGISTRY[key]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Same-family small config for CPU smoke tests: few layers, small
    width/experts/vocab, one forward/train step must run on one CPU."""
    kv = 2 if cfg.n_kv_heads < cfg.n_heads else 4
    moe = None
    if cfg.moe is not None:
        moe = MoECfg(
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            n_shared=min(1, cfg.moe.n_shared),
            capacity_factor=2.0,
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=2 * len(cfg.block_pattern),
        d_model=128,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab=512,
        moe=moe,
        window=(32 if cfg.window else None),
        rnn_width=(128 if cfg.rnn_width else None),
        rwkv_head_dim=32,
        decay_lora=8,
        n_patches=(4 if cfg.n_patches else 0),
        d_patch=(16 if cfg.d_patch else 0),
    )


__all__ = [
    "ArchConfig", "MoECfg", "REGISTRY", "SHAPES", "ShapeSpec",
    "applicable", "get_config", "input_specs", "list_archs", "reduced",
    "n_active_params", "_n_params",
]
