"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, pattern
(rec, rec, local-attn); MQA (kv=1), window 2048. Sub-quadratic: runs
long_500k. [arXiv:2402.19427; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000,
    block_pattern=("rec", "rec", "local"), window=2048,
    rnn_width=2560, conv_width=4, tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2402.19427; hf",
)
