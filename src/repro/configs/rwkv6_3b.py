"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay time-mix +
channel-mix; head_size 64 => 40 heads. Sub-quadratic: runs long_500k.
[arXiv:2404.05892; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab=65536,
    block_pattern=("rwkv",), rwkv_head_dim=64, decay_lora=64,
    sub_quadratic=True,
    source="arXiv:2404.05892; hf",
)
