"""qwen3-0.6b [dense] — qk_norm, GQA, tied embeddings. head_dim=128 is
decoupled from d_model (16*128 != 1024), per the Qwen3 family.
[hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab=151936, qk_norm=True, tie_embeddings=True,
    block_pattern=("attn",),
    source="hf:Qwen/Qwen3-8B; hf",
)
