"""Architecture configuration schema for the assigned-architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_softmax_order: str = "softmax_topk"  # softmax then top-k renorm


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # block structure: cycled over layers. attn | local | rec | rwkv
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp: str = "swiglu"               # swiglu | gelu (musicgen)
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    window: Optional[int] = None      # local attention window
    conv_width: int = 4               # temporal conv for rec blocks
    rnn_width: Optional[int] = None   # RG-LRU state width (default d_model)
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32           # chunked-WKV block length
    decay_lora: int = 64              # rank of the data-dependent decay lora
    frontend: Optional[str] = None    # None | audio | vision
    n_patches: int = 0                # vision stub prefix length
    d_patch: int = 0                  # vision stub patch-embedding dim
    d_frame: int = 0                  # audio stub frame-embedding dim
    sub_quadratic: bool = False       # may run long_500k
    source: str = ""                  # public-literature citation

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

def _n_params(cfg: ArchConfig) -> int:
    """Parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
    d, ff = cfg.d_model, cfg.d_ff
    total = cfg.vocab * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * d
    for li in range(cfg.n_layers):
        kind = cfg.block_pattern[li % len(cfg.block_pattern)]
        if kind in ("attn", "local"):
            total += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        elif kind == "rec":
            r = cfg.rnn_dim
            total += 2 * d * r + r * d + 2 * r + cfg.conv_width * r
        elif kind == "rwkv":
            h = cfg.n_rwkv_heads
            total += 4 * d * d + 2 * cfg.decay_lora * d + h * cfg.rwkv_head_dim
        if kind == "rwkv":
            total += 2 * d * ff  # channel-mix (k, v) + receptance d*d
            total += d * d
        elif cfg.moe is not None:
            m = cfg.moe
            total += d * m.n_experts
            total += (m.n_experts + m.n_shared) * 3 * d * ff
        else:
            nf = 3 if cfg.mlp == "swiglu" else 2
            total += nf * d * ff
        total += 2 * d
    total += d  # final norm
    return total


def n_active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE: only top_k + shared experts)."""
    if cfg.moe is None:
        return _n_params(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    m = cfg.moe
    total = _n_params(cfg)
    inactive = (m.n_experts - m.top_k) * 3 * d * ff * cfg.n_layers
    return total - inactive


ArchConfig.total_params = property(_n_params)  # type: ignore[attr-defined]
