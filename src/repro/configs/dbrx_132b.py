"""dbrx-132b [moe] — 16 experts top-4, fine-grained GLU experts.
[hf:databricks/dbrx-base; unverified]"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    moe=MoECfg(n_experts=16, top_k=4),
    block_pattern=("attn",),
    source="hf:databricks/dbrx-base; unverified",
)
