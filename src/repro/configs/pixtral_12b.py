"""pixtral-12b [vlm] — mistral-nemo backbone; the pixtral ViT is the stub
frontend (input_specs provides precomputed patch embeddings, 256 x 1024 per
image, projected and prepended). [hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    frontend="vision", n_patches=256, d_patch=1024,
    block_pattern=("attn",),
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
