"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400,
    moe=MoECfg(n_experts=64, top_k=6, n_shared=2),
    block_pattern=("attn",),
    source="arXiv:2401.06066; hf",
)
