"""Assigned input-shape sets and ShapeDtypeStruct stand-ins for the dry-run.

LM transformer shapes are seq_len x global_batch. ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache), NOT
``train_step``; ``prefill_*`` lowers the forward pass. ``long_500k`` needs
sub-quadratic attention and only applies to archs with
``cfg.sub_quadratic`` (recurrentgemma-2b, rwkv6-3b) — the skip for pure
full-attention archs is recorded in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                batch_override: int = 0) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends are stubs: pixtral gets precomputed patch embeddings
    (n_patches x d_patch per image, one image per sequence, prepended);
    musicgen gets precomputed EnCodec code ids (vocab 2048).
    """
    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            s_text = s - cfg.n_patches
            specs = {
                "tokens": sds((b, s_text), i32),
                "patches": sds((b, cfg.n_patches, cfg.d_patch), f32),
                "targets": sds((b, s_text), i32),
            }
            if shape.kind == "train":
                specs["loss_mask"] = sds((b, s_text), f32)
            else:
                specs.pop("targets")
            return specs
        specs = {"tokens": sds((b, s), i32)}
        if shape.kind == "train":
            specs["targets"] = sds((b, s), i32)
            specs["loss_mask"] = sds((b, s), f32)
        return specs
    # decode: one new token against a cache of seq_len
    return {"tokens": sds((b,), i32), "pos": sds((b,), i32)}
