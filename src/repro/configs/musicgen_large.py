"""musicgen-large [audio] — decoder-only over EnCodec tokens; the EnCodec
tokenizer is the stub frontend (input_specs provides precomputed code ids).
[arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048,
    mlp="gelu", frontend="audio",
    block_pattern=("attn",),
    source="arXiv:2306.05284; hf",
)
