from .adamw import AdamW, AdamWState, global_norm
from . import compression

__all__ = ["AdamW", "AdamWState", "global_norm", "compression"]
