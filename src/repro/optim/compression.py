"""int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod all-reduce; 4x wire-bytes reduction on the pod axis).

``compress_grads`` quantizes each gradient leaf to int8 with a per-leaf
scale using *stochastic rounding*, keeping the quantization residual in an
error-feedback accumulator so the bias vanishes over steps (1-bit-Adam /
EF21 style). In the pjit path the all-reduce is emitted by XLA inside
autodiff, so the quantizer runs as a grad transform before the optimizer
(wire-compression applies when the optimizer step runs on the reduced
grads); ``compressed_psum`` is the shard_map collective that performs the
actual quantize -> psum -> dequantize on the wire, used by the pipeline/
pod-DP path and benchmarked in tests.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jax.Array, key) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_grads(grads, error_state, key):
    """Quantize grads to int8 (+ error feedback). Returns (dequantized
    grads, new_error_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error_state)
    keys = jax.random.split(key, len(leaves))
    outs, new_err = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32, k)
        deq = q.astype(jnp.float32) * scale
        outs.append(deq.astype(g.dtype))
        new_err.append(g32 - deq)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_err)


def compressed_psum(x: jax.Array, axis_name: str, key) -> jax.Array:
    """Quantize -> psum(int32 accum of int8 payloads) -> dequantize.

    Per-shard scales are all-gathered (tiny) and the max used for shared
    dequantization, so the reduction is exact w.r.t. the quantized payloads.
    Use inside shard_map over the pod axis.
    """
    scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0,
                         axis_name)
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(x / scale + noise), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
