"""AdamW with global-norm clipping and cosine schedule (minimal, optax-like).

State is fp32 throughout (master weights are the fp32 params themselves;
compute casts to bf16 at use — see models/layers.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1

    def schedule(self, step) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1, self.warmup_steps))
        prog = jnp.clip(
            (step - self.warmup_steps)
            / jnp.maximum(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def init(self, params) -> AdamWState:
        def zeros(p):
            return jax.tree.map(jnp.zeros_like, p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState, dict]:
        count = state.count + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)
        lr = self.schedule(count)

        def upd(p, m, n):
            step = m * mu_hat_scale / (jnp.sqrt(n * nu_hat_scale) + self.eps)
            return p - lr * (step + self.weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(count, mu, nu), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
