"""Compile amortization: the MiningPlan AOT executable cache under ragged shapes.

The workload is the failure mode the plan spine exists for: many
mine_arrays calls over streams of *nearby but unequal* lengths. Without
capacity-class bucketing every fresh length is a fresh trace+compile;
with it, lengths sharing a pow2 class share one AOT executable, so the
sweep compiles O(#buckets) times total — and this suite *proves* that,
not just times it: after the cold pass it asserts

    kernel traces == cache misses == distinct cached plans

(one trace per compiled executable, ever) and that the warm pass adds
zero of each. The headline cell is the first-call (trace+compile+run)
vs warm-call (dispatch-only) latency ratio for the ``dense`` engine;
it must show >= ``RATIO_TARGET`` and the harness enforces it with a
raise, not a CSV line. A warm-start cell then measures ``plan.warm`` on
the full bucket set from a cold cache and re-runs the sweep asserting
zero misses — the "preload at startup, never compile mid-session"
protocol of DESIGN.md §11.

Full mode writes the checked-in ``BENCH_compile.json`` baseline;
``REPRO_BENCH_SMOKE=1`` shrinks the sweep to two capacity classes and
writes a throwaway ``BENCH_compile.smoke.json`` sidecar instead.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from repro.core import EventStream, MinerConfig, mine_arrays, plan

from .common import emit

N_TYPES = 4
RATIO_TARGET = 5.0          # first call (trace+compile) vs warm call
HEADLINE_ENGINE = "dense"

# Ragged lengths grouped so each row lands in one pow2 capacity class.
FULL_LENGTHS = (
    33, 40, 48, 52, 60, 64,         # class 64
    70, 84, 100, 112, 120, 128,     # class 128
    130, 160, 192, 224, 250, 256,   # class 256
    260, 320, 384, 448, 500, 512,   # class 512
)
SMOKE_LENGTHS = (33, 48, 60, 70, 100, 120)   # classes 64 + 128


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _stream(n: int, seed: int) -> EventStream:
    rng = np.random.default_rng(seed)
    # round-robin types: every type present at every length, so the level
    # structure (hence the candidate-batch classes) is stable across rows
    types = (np.arange(n) % N_TYPES).astype(np.int32)
    times = np.cumsum(rng.exponential(0.25, n)).astype(np.float32)
    return EventStream(types, times, N_TYPES)


def _cfg(engine: str) -> MinerConfig:
    return MinerConfig(t_low=0.05, t_high=1.0, threshold=2, max_level=3,
                       engine=engine)


def _timed_sweep(lengths, cfg):
    """mine_arrays per length; returns [(n, us, misses_delta), ...]."""
    rows = []
    for i, n in enumerate(lengths):
        stream = _stream(n, seed=i)
        before = plan.cache_stats()["misses"]
        t0 = time.perf_counter()
        mine_arrays(stream, cfg)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((n, us, plan.cache_stats()["misses"] - before))
    return rows


def run() -> None:
    smoke = _smoke()
    lengths = SMOKE_LENGTHS if smoke else FULL_LENGTHS
    engines = ((HEADLINE_ENGINE,) if smoke
               else (HEADLINE_ENGINE, "dense_pallas_fused"))
    report = {"entries": [], "summary": {}}

    for engine in engines:
        cfg = _cfg(engine)
        plan.reset_cache()
        plan.reset_trace_counts()

        cold = _timed_sweep(lengths, cfg)
        traces = sum(plan.trace_counts().values())
        stats = plan.cache_stats()
        n_plans = len(plan.cached_plans())
        # the O(#buckets) claim, as an assertion: every compile is a distinct
        # plan bucket, every bucket compiled exactly once
        if not (traces == stats["misses"] == n_plans):
            raise RuntimeError(
                f"compile accounting broken for {engine}: traces={traces} "
                f"misses={stats['misses']} cached plans={n_plans} — "
                "expected all equal (one trace per bucket, ever)")

        warm = _timed_sweep(lengths, cfg)
        wstats = plan.cache_stats()
        new_traces = sum(plan.trace_counts().values()) - traces
        if wstats["misses"] != stats["misses"] or new_traces:
            raise RuntimeError(
                f"warm pass recompiled for {engine}: "
                f"{wstats['misses'] - stats['misses']} new misses, "
                f"{new_traces} new traces (expected 0)")

        first_us = float(np.median([us for _, us, m in cold if m > 0]))
        warm_us = float(np.median([us for _, us, _ in warm]))
        ratio = first_us / max(warm_us, 1e-9)
        for (n, cus, m), (_, wus, _) in zip(cold, warm):
            report["entries"].append({
                "engine": engine, "n_events": n,
                "cap_class": plan.capacity_class(n),
                "cold_us": cus, "warm_us": wus, "misses": m})
        emit(f"compile_first_call_{engine}", first_us,
             f"buckets={n_plans} calls={len(lengths)} traces={traces}")
        emit(f"compile_warm_call_{engine}", warm_us,
             f"hits={wstats['hits']} ratio={ratio:.1f}x")

        summary = {"buckets": n_plans, "calls": len(lengths),
                   "traces": traces, "misses": stats["misses"],
                   "hits": wstats["hits"], "first_us": first_us,
                   "warm_us": warm_us, "ratio": ratio}

        if engine == HEADLINE_ENGINE:
            # warm-start: preload every bucket from a cold cache, then the
            # whole sweep must run without a single compile
            plans = plan.cached_plans()
            plan.reset_cache()
            t0 = time.perf_counter()
            warmed = plan.warm(plans)
            warm_start_us = (time.perf_counter() - t0) * 1e6
            replay = _timed_sweep(lengths, cfg)
            rstats = plan.cache_stats()
            if rstats["misses"]:
                raise RuntimeError(
                    f"sweep after warm({len(plans)} plans) still compiled "
                    f"{rstats['misses']} time(s) — warm-start preload is "
                    "not covering the workload")
            emit("compile_warm_start", warm_start_us,
                 f"plans={len(plans)} compiled={warmed['compiled']} "
                 f"replay_misses={rstats['misses']}")
            summary["warm_start_us"] = warm_start_us
            summary["warm_start_plans"] = len(plans)
            summary["replay_warm_us"] = float(
                np.median([us for _, us, _ in replay]))

            verdict = "PASS" if ratio >= RATIO_TARGET else "FAIL"
            emit("compile_headline_ratio", first_us,
                 f"{ratio:.1f}x first-vs-warm ({engine}, "
                 f"target >={RATIO_TARGET:.0f}x: {verdict})")
            if ratio < RATIO_TARGET:
                # a real gate, not a CSV line someone has to read
                raise RuntimeError(
                    f"compile-cache headline ratio {ratio:.1f}x is below "
                    f"the >={RATIO_TARGET:.0f}x target (engine {engine})")
        report["summary"][engine] = summary

    import jax
    path = pathlib.Path(
        "BENCH_compile.smoke.json" if smoke else "BENCH_compile.json")
    path.write_text(json.dumps(
        {"backend": jax.default_backend(), "suite": "compile_cache",
         **report}, indent=2) + "\n")
    emit("compile_json_written", 0.0, str(path))
