"""Paper Fig 11: counting time vs episode length (compaction comparison).

Methods: CountScanWrite (lock-free, backward), AtomicCompact analogue
(forward + final sort), CudppCompact analogue (flag-scan), plus the
beyond-paper dense engine. Episode length sweeps 2..9 on dataset 1
(time-scaled), mirroring the paper's x-axis.
"""
from __future__ import annotations


from repro.core import count_batch
from repro.core.episodes import episode_batch
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset

from .common import emit, time_fn

ENGINES = ("count_scan_write", "atomic_sort", "flags", "dense", "dense_pallas")


def run() -> None:
    cfg = NetworkConfig()
    stream = paper_dataset(1, scale=0.003)
    n = stream.n_events
    cap = int(n)
    base = embedded_episodes(cfg)[0]

    for length in (2, 3, 4, 5, 7, 9):
        ep = base.subepisode(0, length)
        sym, lo, hi = episode_batch([ep])
        for engine in ENGINES:
            kw = {}
            if engine not in ("dense", "dense_pallas"):
                kw = dict(cap_occ=4 * cap, max_window=32)
            us = time_fn(
                lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                    n_types=stream.n_types, cap=cap,
                                    engine=engine, **kw))
            emit(f"fig11_len{length}_{engine}", us, f"n_events={n}")
