"""Benchmark harness: one module per paper table/figure. Emits
``name,us_per_call,derived`` CSV lines (benchmarks/common.py).

``--compare BASELINE.json`` re-runs the counting engine sweep and prints
per-engine speedups against the checked-in baseline (the perf-trajectory
gate of DESIGN.md §6): exits nonzero when the baseline's fastest engine in
any cell regresses by more than REGRESSION_THRESHOLD.
"""
import argparse
import json
import sys
import traceback

REGRESSION_THRESHOLD = 0.25   # fastest engine may not slow down >25%


def _cell_key(entry) -> tuple:
    return (entry["episode_len"], entry["n_events"], entry.get("batch"),
            entry.get("scheduler", "scan"))


def compare_entries(baseline, new, threshold=REGRESSION_THRESHOLD):
    """Compare sweep entry lists; returns (report_lines, regressions).

    Speedup = baseline_us / new_us (>1 is faster). A regression is the
    *baseline-fastest* engine of any (episode_len, n_events, batch,
    scheduler) cell slowing down by more than ``threshold`` — or going
    missing from the new sweep entirely (an unmeasured fastest engine is an
    ungated cell, not a pass). New engines or cells absent from the
    baseline are reported but never gate.
    """
    base_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in baseline}
    new_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in new}
    lines, regressions = [], []
    for e in new:
        key = _cell_key(e)
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        base_us = base_by.get((key, e["engine"]))
        if base_us is None:
            lines.append(f"{tag} {e['engine']}: {e['us_per_call']:.1f}us (new)")
        else:
            speedup = base_us / max(e["us_per_call"], 1e-9)
            lines.append(
                f"{tag} {e['engine']}: {e['us_per_call']:.1f}us "
                f"({speedup:.2f}x vs baseline {base_us:.1f}us)")
    fastest = {}
    for e in baseline:
        key = _cell_key(e)
        if key not in fastest or e["us_per_call"] < fastest[key][1]:
            fastest[key] = (e["engine"], e["us_per_call"])
    for key, (engine, base_us) in sorted(fastest.items()):
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        new_us = new_by.get((key, engine))
        if new_us is None:
            regressions.append(
                f"{tag} {engine}: baseline-fastest engine missing from the "
                f"new sweep — cell not gated")
        elif new_us > (1.0 + threshold) * base_us:
            regressions.append(
                f"{tag} {engine}: {base_us:.1f}us -> {new_us:.1f}us "
                f"(>{threshold:.0%} regression of the fastest engine)")
    return lines, regressions


def matched_cells(baseline, new) -> int:
    """(cell, engine) pairs present in both entry lists — the gate is
    vacuous (and must fail) when nothing overlaps."""
    base_keys = {(_cell_key(e), e["engine"]) for e in baseline}
    return sum(1 for e in new if (_cell_key(e), e["engine"]) in base_keys)


def run_compare(baseline_path: str) -> int:
    import pathlib

    from . import bench_counting
    with open(baseline_path) as f:
        baseline = json.load(f)["entries"]
    # sidecar output: the gate must never overwrite the baseline it reads
    new = bench_counting.run_engine_sweep(
        json_path=pathlib.Path("BENCH_counting.compare.json"))
    lines, regressions = compare_entries(baseline, new)
    print(f"\n== compare vs {baseline_path} ==")
    for line in lines:
        print(line)
    if not matched_cells(baseline, new):
        print("\nERROR: no sweep cell overlaps the baseline — nothing was "
              "gated (is REPRO_BENCH_SMOKE set, or is the baseline from a "
              "different sweep configuration?)")
        return 1
    if regressions:
        print("\nREGRESSIONS:")
        for r in regressions:
            print(r)
        return 1
    print("\nno regression of any cell's fastest engine "
          f"(threshold {REGRESSION_THRESHOLD:.0%})")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: counting,mining,episode_length,"
                         "frequency,instruction_mix,distributed")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="re-run the counting sweep and gate against a "
                         "checked-in BENCH_counting.json baseline")
    args = ap.parse_args()
    if args.compare:
        raise SystemExit(run_compare(args.compare))
    from . import (bench_counting, bench_distributed, bench_episode_length,
                   bench_frequency, bench_instruction_mix, bench_mining)
    suites = {
        "counting": bench_counting.run,            # paper Figs 9-10 + engine sweep
        "mining": bench_mining.run,                # device-resident miner e2e
        "episode_length": bench_episode_length.run,  # paper Fig 11
        "frequency": bench_frequency.run,          # paper Fig 12
        "instruction_mix": bench_instruction_mix.run,  # paper Table III
        "distributed": bench_distributed.run,      # beyond-paper scaling
    }
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failed = 0
    for name in chosen:
        try:
            suites[name]()
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
