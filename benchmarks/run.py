"""Benchmark harness: one module per paper table/figure. Emits
``name,us_per_call,derived`` CSV lines (benchmarks/common.py).

``--compare BASELINE.json`` re-runs the counting engine sweep and prints
per-engine speedups against the checked-in baseline (the perf-trajectory
gate of DESIGN.md §6): exits nonzero when the baseline's fastest engine in
any cell regresses by more than REGRESSION_THRESHOLD, or when the fused
single-launch engine is not the min-time engine of every cell (within
FUSED_TOLERANCE — the documented noise bound on a shared CPU container).

``--autotune`` wall-clocks the model-ranked tile candidates for every bench
bucket and regenerates ``src/repro/kernels/tuned_configs.json`` (the table
``kernels.autotune.resolve`` serves to the hot path).
"""
import argparse
import json
import subprocess
import sys
import traceback
from pathlib import Path

REGRESSION_THRESHOLD = 0.25   # fastest engine may not slow down >25%
FUSED_ENGINE = "dense_pallas_fused"
FUSED_TOLERANCE = 0.05        # fused must win each cell, or tie within 5%


def _cell_key(entry) -> tuple:
    return (entry["episode_len"], entry["n_events"], entry.get("batch"),
            entry.get("scheduler", "scan"))


def compare_entries(baseline, new, threshold=REGRESSION_THRESHOLD):
    """Compare sweep entry lists; returns (report_lines, regressions).

    Speedup = baseline_us / new_us (>1 is faster). A regression is the
    *baseline-fastest* engine of any (episode_len, n_events, batch,
    scheduler) cell slowing down by more than ``threshold`` — or going
    missing from the new sweep entirely (an unmeasured fastest engine is an
    ungated cell, not a pass). New engines or cells absent from the
    baseline are reported but never gate.
    """
    base_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in baseline}
    new_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in new}
    lines, regressions = [], []
    for e in new:
        key = _cell_key(e)
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        base_us = base_by.get((key, e["engine"]))
        if base_us is None:
            lines.append(f"{tag} {e['engine']}: {e['us_per_call']:.1f}us (new)")
        else:
            speedup = base_us / max(e["us_per_call"], 1e-9)
            lines.append(
                f"{tag} {e['engine']}: {e['us_per_call']:.1f}us "
                f"({speedup:.2f}x vs baseline {base_us:.1f}us)")
    fastest = {}
    for e in baseline:
        key = _cell_key(e)
        if key not in fastest or e["us_per_call"] < fastest[key][1]:
            fastest[key] = (e["engine"], e["us_per_call"])
    for key, (engine, base_us) in sorted(fastest.items()):
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        new_us = new_by.get((key, engine))
        if new_us is None:
            regressions.append(
                f"{tag} {engine}: baseline-fastest engine missing from the "
                "new sweep — cell not gated")
        elif new_us > (1.0 + threshold) * base_us:
            regressions.append(
                f"{tag} {engine}: {base_us:.1f}us -> {new_us:.1f}us "
                f"(>{threshold:.0%} regression of the fastest engine)")
    return lines, regressions


def matched_cells(baseline, new) -> int:
    """(cell, engine) pairs present in both entry lists — the gate is
    vacuous (and must fail) when nothing overlaps."""
    base_keys = {(_cell_key(e), e["engine"]) for e in baseline}
    return sum(1 for e in new if (_cell_key(e), e["engine"]) in base_keys)


def best_entries(*entry_lists) -> list:
    """Per-(cell, engine) fastest entry across repeated sweeps.

    Shared-machine interference is additive, so the min over independent
    runs approximates the true cost; the gate retries with this so a noisy
    neighbor cannot fail it, while a *persistent* regression still does
    (it is just as slow on every re-measure)."""
    by = {}
    for e in (entry for entries in entry_lists for entry in entries):
        k = (_cell_key(e), e["engine"])
        if k not in by or e["us_per_call"] < by[k]["us_per_call"]:
            by[k] = e
    return list(by.values())


def fused_cell_failures(entries, tolerance=FUSED_TOLERANCE,
                        fused=FUSED_ENGINE) -> list:
    """Cells where ``fused`` is not the min-time engine (beyond tolerance).

    The single-launch pipeline's headline claim is that it wins EVERY
    (episode_len, n_events, batch, scheduler) cell; a cell it loses — or is
    absent from — is a failure line naming the actual winner, so the gate's
    error output is the per-cell winner table.
    """
    cells = {}
    for e in entries:
        cells.setdefault(_cell_key(e), []).append(e)
    failures = []
    for key, es in sorted(cells.items()):
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        winner = min(es, key=lambda e: e["us_per_call"])
        fused_us = {e["engine"]: e["us_per_call"] for e in es}.get(fused)
        if fused_us is None:
            failures.append(f"{tag}: no {fused} entry — cell not covered")
        elif fused_us > (1.0 + tolerance) * winner["us_per_call"]:
            failures.append(
                f"{tag}: winner {winner['engine']} "
                f"{winner['us_per_call']:.1f}us, {fused} {fused_us:.1f}us "
                f"({fused_us / max(winner['us_per_call'], 1e-9):.2f}x)")
    return failures


def run_compare(baseline_path: str,
                threshold: float = REGRESSION_THRESHOLD,
                fused_tolerance: float = FUSED_TOLERANCE) -> int:
    import pathlib

    from . import bench_counting
    with open(baseline_path) as f:
        baseline = json.load(f)["entries"]
    # sidecar output: the gate must never overwrite the baseline it reads
    sidecar = pathlib.Path("BENCH_counting.compare.json")
    new = bench_counting.run_engine_sweep(json_path=sidecar)
    lines, regressions = compare_entries(baseline, new, threshold=threshold)
    fused_losses = fused_cell_failures(new, tolerance=fused_tolerance)
    # one noise retry, and only for slowdowns: a baseline-fastest engine
    # MISSING from the sweep is deterministic — re-measuring cannot fix it
    if any("missing" not in r for r in regressions) or fused_losses:
        print(f"\n{len(regressions) + len(fused_losses)} cell(s) over "
              "threshold — re-measuring once to separate interference from "
              "real regressions")
        import jax

        new = best_entries(new, bench_counting.run_engine_sweep(
            json_path=sidecar))
        sidecar.write_text(json.dumps(
            {"backend": jax.default_backend(),
             "suite": "counting_engine_sweep", "retry": "best-of-2",
             "entries": new}, indent=2) + "\n")
        lines, regressions = compare_entries(baseline, new,
                                             threshold=threshold)
        fused_losses = fused_cell_failures(new, tolerance=fused_tolerance)
    print(f"\n== compare vs {baseline_path} ==")
    for line in lines:
        print(line)
    if not matched_cells(baseline, new):
        print("\nERROR: no sweep cell overlaps the baseline — nothing was "
              "gated (is REPRO_BENCH_SMOKE set, or is the baseline from a "
              "different sweep configuration?)")
        return 1
    failed = False
    if regressions:
        failed = True
        print("\nREGRESSIONS:")
        for r in regressions:
            print(r)
    if fused_losses:
        failed = True
        print(f"\nFUSED ENGINE NOT MIN-TIME (tolerance "
              f"{fused_tolerance:.0%}):")
        for r in fused_losses:
            print(r)
    if failed:
        return 1
    print("\nno regression of any cell's fastest engine "
          f"(threshold {threshold:.0%}); "
          f"{FUSED_ENGINE} is min-time in every cell "
          f"(tolerance {fused_tolerance:.0%})")
    return 0


def run_autotune(top_k: int = 3, out_path: str | None = None) -> int:
    """Regenerate the tuned-tile table over the bench sweep buckets.

    For every (kind, levels, n_events, batch) bucket the counting sweep
    exercises, the roofline cost model pre-ranks the candidate tile grid
    (``autotune.rank_candidates``) and the ``top_k`` survivors are
    wall-clocked on the real dispatch path; the winner is written to
    ``kernels/tuned_configs.json``. Smoke mode shrinks the grid and writes
    a throwaway sidecar so CI never clobbers the checked-in table.
    """
    import os
    import pathlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import serial, tracking
    from repro.core.counting import count_batch_dispatch
    from repro.core.episodes import episode_batch
    from repro.core.events import type_index
    from repro.kernels import autotune

    from . import bench_counting
    from .common import emit, time_fn

    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    stream_sizes = (256,) if smoke else bench_counting.SWEEP_STREAM_SIZES
    episode_lengths = ((3,) if smoke
                       else bench_counting.SWEEP_EPISODE_LENGTHS)
    batches = (4,) if smoke else bench_counting.SWEEP_BATCHES
    warmup, iters = (1, 1) if smoke else (1, 3)
    # kind "count": the fused single-launch pipeline; kind "track": the
    # track-then-schedule path (what the sharded miner still runs)
    kind_engine = {"count": "dense_pallas_fused", "track": "dense_pallas"}

    configs = {}
    for n_events in stream_sizes:
        types, times, n_types = bench_counting._sweep_stream(n_events)
        table, _ = type_index(types, times, n_types, n_events)
        for ep_len in episode_lengths:
            rng = np.random.default_rng(ep_len)
            for batch in batches:
                eps = [serial(rng.integers(0, n_types, ep_len).tolist(),
                              0.1, 2.0)
                       for _ in range(batch)]
                sym, lo, hi = episode_batch(eps)
                tbs = table[sym]
                pe = jnp.full((batch,), -jnp.inf, jnp.float32)
                pc = jnp.zeros((batch,), jnp.int32)
                levels = ep_len - 1
                for kind, engine in kind_engine.items():
                    key = autotune.bucket_key(kind, levels, n_events, batch)
                    best = None
                    for cand in autotune.rank_candidates(
                            kind, levels, n_events, batch, top_k=top_k):
                        cfg = tracking.EngineConfig(
                            block_next=cand.block_next,
                            block_prev=cand.block_prev,
                            window_tiles=cand.window_tiles,
                            chunk=cand.chunk)

                        # staticcheck: disable=REPRO003 -- autotune probe
                        # deliberately times the raw jitted dispatch path
                        @jax.jit
                        def fn(tbs, lo, hi, pe, pc, _cfg=cfg):
                            return count_batch_dispatch(
                                engine, tbs, lo, hi, pe, pc, _cfg)

                        us = time_fn(fn, tbs, lo, hi, pe, pc,
                                     warmup=warmup, iters=iters)
                        emit(f"autotune_{key}_bn{cand.block_next}"
                             f"_c{cand.chunk}", us, "")
                        if best is None or us < best[0]:
                            best = (us, cand)
                    configs[key] = best[1].asdict()
                    emit(f"autotune_{key}_winner", best[0],
                         ";".join(f"{k}={v}"
                                  for k, v in configs[key].items()))
    path = pathlib.Path(
        out_path or ("tuned_configs.smoke.json" if smoke
                     else autotune._CONFIG_PATH))
    path.write_text(json.dumps(
        {"backend": jax.default_backend(),
         "suite": "kernel_tile_autotune",
         "configs": configs}, indent=2) + "\n")
    autotune.clear_cache()
    emit("autotune_json_written", 0.0, str(path))
    return 0


SUITE_NAMES = ("counting", "mining", "corpus", "streaming", "serving",
               "episode_length", "frequency", "instruction_mix",
               "distributed", "compile", "staticcheck")


def _run_staticcheck() -> None:
    """Shell to scripts/staticcheck.py --all: the bench harness is the one
    entry point every CI smoke already exercises, so a broken checker (or a
    dirty tree) fails fast here too."""
    script = Path(__file__).resolve().parents[1] / "scripts" / "staticcheck.py"
    subprocess.run([sys.executable, str(script), "--all"], check=True)


def unknown_suites(chosen) -> list:
    """Names in ``chosen`` that are not benchmark suites (order kept)."""
    return [name for name in chosen if name not in SUITE_NAMES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites to run; valid: "
                         + ",".join(SUITE_NAMES))
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="re-run the counting sweep and gate against a "
                         "checked-in BENCH_counting.json baseline")
    ap.add_argument("--threshold", type=float, default=REGRESSION_THRESHOLD,
                    help="allowed fractional slowdown of each cell's "
                         "baseline-fastest engine before --compare fails "
                         f"(default {REGRESSION_THRESHOLD}; CI uses a looser "
                         "bound because runners differ from the machine the "
                         "baseline was measured on)")
    ap.add_argument("--fused-threshold", type=float, default=FUSED_TOLERANCE,
                    help="allowed fractional gap between the fused engine "
                         "and a cell's min-time engine before --compare "
                         f"fails (default {FUSED_TOLERANCE}: the documented "
                         "timer-noise bound; mirrors --threshold)")
    ap.add_argument("--autotune", action="store_true",
                    help="wall-clock the model-ranked tile candidates per "
                         "bench bucket and regenerate "
                         "src/repro/kernels/tuned_configs.json")
    ap.add_argument("--autotune-topk", type=int, default=3,
                    help="model-ranked candidates to wall-clock per bucket "
                         "in --autotune (default 3)")
    args = ap.parse_args()
    if args.autotune:
        raise SystemExit(run_autotune(top_k=args.autotune_topk))
    if args.compare:
        raise SystemExit(run_compare(args.compare, threshold=args.threshold,
                                     fused_tolerance=args.fused_threshold))
    chosen = args.only.split(",") if args.only else list(SUITE_NAMES)
    # validate BEFORE importing/running anything: a typo'd suite name must
    # be a loud usage error listing the valid names, not a skipped suite a
    # CI smoke step could false-pass on
    unknown = unknown_suites(chosen)
    if unknown:
        ap.error(f"unknown suite(s) {','.join(unknown)!r}; "
                 f"valid suites: {', '.join(SUITE_NAMES)}")
    from . import (bench_compile, bench_corpus, bench_counting,
                   bench_distributed, bench_episode_length, bench_frequency,
                   bench_instruction_mix, bench_mining, bench_serving,
                   bench_streaming)
    suites = {
        "counting": bench_counting.run,            # paper Figs 9-10 + engine sweep
        "mining": bench_mining.run,                # device-resident miner e2e
        "corpus": bench_corpus.run,                # multi-stream batched miner
        "streaming": bench_streaming.run,          # incremental append vs remine
        "serving": bench_serving.run,              # session pool vs miner loop
        "episode_length": bench_episode_length.run,  # paper Fig 11
        "frequency": bench_frequency.run,          # paper Fig 12
        "instruction_mix": bench_instruction_mix.run,  # paper Table III
        "distributed": bench_distributed.run,      # beyond-paper scaling
        "compile": bench_compile.run,              # AOT plan-cache amortization
        "staticcheck": _run_staticcheck,           # invariant checker (cheap)
    }
    print("name,us_per_call,derived")
    failed = 0
    for name in chosen:
        try:
            suites[name]()
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
