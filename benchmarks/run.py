"""Benchmark harness: one module per paper table/figure. Emits
``name,us_per_call,derived`` CSV lines (benchmarks/common.py).

``--compare BASELINE.json`` re-runs the counting engine sweep and prints
per-engine speedups against the checked-in baseline (the perf-trajectory
gate of DESIGN.md §6): exits nonzero when the baseline's fastest engine in
any cell regresses by more than REGRESSION_THRESHOLD.
"""
import argparse
import json
import sys
import traceback

REGRESSION_THRESHOLD = 0.25   # fastest engine may not slow down >25%


def _cell_key(entry) -> tuple:
    return (entry["episode_len"], entry["n_events"], entry.get("batch"),
            entry.get("scheduler", "scan"))


def compare_entries(baseline, new, threshold=REGRESSION_THRESHOLD):
    """Compare sweep entry lists; returns (report_lines, regressions).

    Speedup = baseline_us / new_us (>1 is faster). A regression is the
    *baseline-fastest* engine of any (episode_len, n_events, batch,
    scheduler) cell slowing down by more than ``threshold`` — or going
    missing from the new sweep entirely (an unmeasured fastest engine is an
    ungated cell, not a pass). New engines or cells absent from the
    baseline are reported but never gate.
    """
    base_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in baseline}
    new_by = {(_cell_key(e), e["engine"]): e["us_per_call"] for e in new}
    lines, regressions = [], []
    for e in new:
        key = _cell_key(e)
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        base_us = base_by.get((key, e["engine"]))
        if base_us is None:
            lines.append(f"{tag} {e['engine']}: {e['us_per_call']:.1f}us (new)")
        else:
            speedup = base_us / max(e["us_per_call"], 1e-9)
            lines.append(
                f"{tag} {e['engine']}: {e['us_per_call']:.1f}us "
                f"({speedup:.2f}x vs baseline {base_us:.1f}us)")
    fastest = {}
    for e in baseline:
        key = _cell_key(e)
        if key not in fastest or e["us_per_call"] < fastest[key][1]:
            fastest[key] = (e["engine"], e["us_per_call"])
    for key, (engine, base_us) in sorted(fastest.items()):
        tag = f"len={key[0]} n={key[1]} batch={key[2]} sched={key[3]}"
        new_us = new_by.get((key, engine))
        if new_us is None:
            regressions.append(
                f"{tag} {engine}: baseline-fastest engine missing from the "
                "new sweep — cell not gated")
        elif new_us > (1.0 + threshold) * base_us:
            regressions.append(
                f"{tag} {engine}: {base_us:.1f}us -> {new_us:.1f}us "
                f"(>{threshold:.0%} regression of the fastest engine)")
    return lines, regressions


def matched_cells(baseline, new) -> int:
    """(cell, engine) pairs present in both entry lists — the gate is
    vacuous (and must fail) when nothing overlaps."""
    base_keys = {(_cell_key(e), e["engine"]) for e in baseline}
    return sum(1 for e in new if (_cell_key(e), e["engine"]) in base_keys)


def best_entries(*entry_lists) -> list:
    """Per-(cell, engine) fastest entry across repeated sweeps.

    Shared-machine interference is additive, so the min over independent
    runs approximates the true cost; the gate retries with this so a noisy
    neighbor cannot fail it, while a *persistent* regression still does
    (it is just as slow on every re-measure)."""
    by = {}
    for e in (entry for entries in entry_lists for entry in entries):
        k = (_cell_key(e), e["engine"])
        if k not in by or e["us_per_call"] < by[k]["us_per_call"]:
            by[k] = e
    return list(by.values())


def run_compare(baseline_path: str,
                threshold: float = REGRESSION_THRESHOLD) -> int:
    import pathlib

    from . import bench_counting
    with open(baseline_path) as f:
        baseline = json.load(f)["entries"]
    # sidecar output: the gate must never overwrite the baseline it reads
    sidecar = pathlib.Path("BENCH_counting.compare.json")
    new = bench_counting.run_engine_sweep(json_path=sidecar)
    lines, regressions = compare_entries(baseline, new, threshold=threshold)
    # one noise retry, and only for slowdowns: a baseline-fastest engine
    # MISSING from the sweep is deterministic — re-measuring cannot fix it
    if any("missing" not in r for r in regressions):
        print(f"\n{len(regressions)} cell(s) over threshold — re-measuring "
              "once to separate interference from real regressions")
        import jax

        new = best_entries(new, bench_counting.run_engine_sweep(
            json_path=sidecar))
        sidecar.write_text(json.dumps(
            {"backend": jax.default_backend(),
             "suite": "counting_engine_sweep", "retry": "best-of-2",
             "entries": new}, indent=2) + "\n")
        lines, regressions = compare_entries(baseline, new,
                                             threshold=threshold)
    print(f"\n== compare vs {baseline_path} ==")
    for line in lines:
        print(line)
    if not matched_cells(baseline, new):
        print("\nERROR: no sweep cell overlaps the baseline — nothing was "
              "gated (is REPRO_BENCH_SMOKE set, or is the baseline from a "
              "different sweep configuration?)")
        return 1
    if regressions:
        print("\nREGRESSIONS:")
        for r in regressions:
            print(r)
        return 1
    print("\nno regression of any cell's fastest engine "
          f"(threshold {threshold:.0%})")
    return 0


SUITE_NAMES = ("counting", "mining", "corpus", "streaming", "episode_length",
               "frequency", "instruction_mix", "distributed")


def unknown_suites(chosen) -> list:
    """Names in ``chosen`` that are not benchmark suites (order kept)."""
    return [name for name in chosen if name not in SUITE_NAMES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites to run; valid: "
                         + ",".join(SUITE_NAMES))
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="re-run the counting sweep and gate against a "
                         "checked-in BENCH_counting.json baseline")
    ap.add_argument("--threshold", type=float, default=REGRESSION_THRESHOLD,
                    help="allowed fractional slowdown of each cell's "
                         "baseline-fastest engine before --compare fails "
                         f"(default {REGRESSION_THRESHOLD}; CI uses a looser "
                         "bound because runners differ from the machine the "
                         "baseline was measured on)")
    args = ap.parse_args()
    if args.compare:
        raise SystemExit(run_compare(args.compare, threshold=args.threshold))
    chosen = args.only.split(",") if args.only else list(SUITE_NAMES)
    # validate BEFORE importing/running anything: a typo'd suite name must
    # be a loud usage error listing the valid names, not a skipped suite a
    # CI smoke step could false-pass on
    unknown = unknown_suites(chosen)
    if unknown:
        ap.error(f"unknown suite(s) {','.join(unknown)!r}; "
                 f"valid suites: {', '.join(SUITE_NAMES)}")
    from . import (bench_corpus, bench_counting, bench_distributed,
                   bench_episode_length, bench_frequency,
                   bench_instruction_mix, bench_mining, bench_streaming)
    suites = {
        "counting": bench_counting.run,            # paper Figs 9-10 + engine sweep
        "mining": bench_mining.run,                # device-resident miner e2e
        "corpus": bench_corpus.run,                # multi-stream batched miner
        "streaming": bench_streaming.run,          # incremental append vs remine
        "episode_length": bench_episode_length.run,  # paper Fig 11
        "frequency": bench_frequency.run,          # paper Fig 12
        "instruction_mix": bench_instruction_mix.run,  # paper Table III
        "distributed": bench_distributed.run,      # beyond-paper scaling
    }
    print("name,us_per_call,derived")
    failed = 0
    for name in chosen:
        try:
            suites[name]()
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
