"""Benchmark harness: one module per paper table/figure. Emits
``name,us_per_call,derived`` CSV lines (benchmarks/common.py)."""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: counting,mining,episode_length,"
                         "frequency,instruction_mix,distributed")
    args = ap.parse_args()
    from . import (bench_counting, bench_distributed, bench_episode_length,
                   bench_frequency, bench_instruction_mix, bench_mining)
    suites = {
        "counting": bench_counting.run,            # paper Figs 9-10 + engine sweep
        "mining": bench_mining.run,                # device-resident miner e2e
        "episode_length": bench_episode_length.run,  # paper Fig 11
        "frequency": bench_frequency.run,          # paper Fig 12
        "instruction_mix": bench_instruction_mix.run,  # paper Table III
        "distributed": bench_distributed.run,      # beyond-paper scaling
    }
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failed = 0
    for name in chosen:
        try:
            suites[name]()
        except Exception:
            failed += 1
            print(f"{name},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
