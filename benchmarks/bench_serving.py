"""Multi-tenant serving throughput: pooled sessions vs per-session miners.

The workload is the serving loop ``core.serving`` exists for: ``S``
concurrent sessions, each appending a chunk per round, with every
session's full-stream mining result needed after every round. The
baseline is the pre-serving architecture — a Python loop of standalone
``StreamingMiner``s, paying the per-dispatch overhead and the per-level
host sync ``S`` times per round — while ``MiningSessionServer`` absorbs
all ``S`` appends in ONE batched level loop (a fixed number of
dispatches and host syncs per round, regardless of ``S``).

Both paths are warmed before timing, the pool's capacity classes are
pinned so nothing grows mid-measurement, and the serving path must run
the timed rounds with ZERO plan-cache misses (the ``warm()`` protocol's
contract — asserted, not reported). The headline cell (``dense`` engine,
``S`` >= 1k sessions) must show >= 5x session throughput over the loop
and the harness enforces it: a shortfall raises, it does not hide in a
CSV column.

Emits the throughput (sessions/sec) and the p99 append-completion
latency of both paths. For the pool, one round absorbs every append in
one flush, so the round's wall time bounds EVERY append's completion
latency that round: p99 is taken over per-round flush times. For the
loop, each append completes individually: p99 is over per-append times.

Writes ``BENCH_serving.json`` (``BENCH_serving.smoke.json`` under
``REPRO_BENCH_SMOKE=1`` — CI must never clobber a checked-in baseline)
and, when a checked-in ``BENCH_serving.json`` baseline exists, a
``BENCH_serving.compare.json`` sidecar with per-metric ratios (the
perf-trajectory artifact; the >=5x raise is the gate).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from repro.core import MinerConfig, MiningSessionServer, StreamingMiner, plan

from .common import emit

N_TYPES = 8
SPEEDUP_TARGET = 5.0
HEADLINE_ENGINE = "dense"


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _feeds(seed: int, n_sessions: int, n_rounds: int, chunk: int):
    """Per-session chunk sequences (independent arrival processes)."""
    rng = np.random.default_rng(seed)
    feeds = []
    for _ in range(n_sessions):
        times = np.cumsum(rng.exponential(0.25, n_rounds * chunk))
        types = rng.integers(0, N_TYPES, n_rounds * chunk).astype(np.int32)
        feeds.append([(types[r * chunk:(r + 1) * chunk],
                       times[r * chunk:(r + 1) * chunk].astype(np.float32))
                      for r in range(n_rounds)])
    return feeds


def _serve_round(srv, sids, feeds, r) -> float:
    """One serving round: queue every session's chunk, one batched flush.
    Returns the round's wall time in us — an upper bound on every queued
    append's completion latency."""
    t0 = time.perf_counter()
    for sid, feed in zip(sids, feeds):
        srv.append(sid, *feed[r])
    srv.flush()
    return (time.perf_counter() - t0) * 1e6


def _loop_round(miners, feeds, r) -> list:
    """One baseline round: each session's miner appends (and mines)
    individually. Returns per-append completion times in us."""
    out = []
    for m, feed in zip(miners, feeds):
        t0 = time.perf_counter()
        m.append(*feed[r])
        out.append((time.perf_counter() - t0) * 1e6)
    return out


def run() -> None:
    smoke = _smoke()
    n_sessions = 48 if smoke else 1024
    chunk = 16 if smoke else 24
    warm_rounds = 1
    timed_rounds = 2 if smoke else 3
    n_rounds = warm_rounds + timed_rounds
    engine = HEADLINE_ENGINE
    target = 2.0 if smoke else SPEEDUP_TARGET
    # threshold scaled to keep the frequent frontier small; max_candidates
    # pinned to one batch class so every level's dispatch chunks land in
    # the {16,32,64} classes the warm() call below enumerates (the serving
    # protocol: an operator bounds the candidate valve, then warms exactly
    # the classes traffic can reach) — the loop baseline runs the same
    # valve, so the comparison stays like-for-like
    threshold = max(4, (n_rounds * chunk) // (2 * N_TYPES))
    cap = n_rounds * chunk   # worst case: every event one type
    cfg = MinerConfig(t_low=0.05, t_high=1.0, threshold=threshold,
                      max_level=3, engine=engine, cap=cap,
                      max_candidates=N_TYPES * N_TYPES)
    feeds = _feeds(0, n_sessions, n_rounds, chunk)

    # -- pooled serving path ------------------------------------------------
    srv = MiningSessionServer(N_TYPES, cfg, max_sessions=n_sessions,
                              initial_cap=cap)
    # batch classes up to the max_candidates valve; tail classes up to the
    # span-bounded suffix (chunk arrivals x the (t_low, t_high] window)
    srv.warm(batches=[16, 32, 64], tail_caps=[16, 32, 64])
    sids = [srv.create_session() for _ in range(n_sessions)]
    for r in range(warm_rounds):
        _serve_round(srv, sids, feeds, r)
    misses_before = plan.cache_stats()["misses"]
    serve_times = [_serve_round(srv, sids, feeds, r)
                   for r in range(warm_rounds, n_rounds)]
    misses = plan.cache_stats()["misses"] - misses_before
    # the warm() contract, asserted where it matters: live traffic on a
    # warmed, capacity-pinned pool never misses the plan cache
    if misses:
        raise RuntimeError(
            f"serving timed rounds had {misses} plan-cache miss(es) after "
            "warm() — a capacity class was not covered by the warm protocol")
    serve_round_us = float(np.mean(serve_times))
    serve_p99_us = float(np.percentile(serve_times, 99))
    serve_rate = n_sessions / (serve_round_us / 1e6)

    # -- per-session loop baseline -----------------------------------------
    # every miner shares the same capacity classes, so the loop compiles
    # once across all S miners (its best case), warmed on the first round
    miners = [StreamingMiner(N_TYPES, cfg, initial_cap=cap)
              for _ in range(n_sessions)]
    for r in range(warm_rounds):
        _loop_round(miners, feeds, r)
    loop_samples = []
    for r in range(warm_rounds, n_rounds):
        loop_samples.extend(_loop_round(miners, feeds, r))
    loop_round_us = float(np.mean(loop_samples)) * n_sessions
    loop_p99_us = float(np.percentile(loop_samples, 99))
    loop_rate = n_sessions / (loop_round_us / 1e6)

    speedup = loop_round_us / max(serve_round_us, 1e-9)
    tag = f"S={n_sessions} chunk={chunk}/round"
    emit(f"serving_loop_{engine}", loop_round_us,
         f"{tag} {loop_rate:.0f} sessions/sec p99={loop_p99_us:.0f}us")
    emit(f"serving_pool_{engine}", serve_round_us,
         f"{tag} {serve_rate:.0f} sessions/sec p99={serve_p99_us:.0f}us "
         f"speedup={speedup:.1f}x")
    verdict = "PASS" if speedup >= target else "FAIL"
    emit("serving_headline_speedup", serve_round_us,
         f"{speedup:.1f}x vs per-session loop ({engine}, S={n_sessions}, "
         f"target >={target:.0f}x: {verdict})")

    entries = [{
        "engine": engine, "sessions": n_sessions, "chunk": chunk,
        "timed_rounds": timed_rounds,
        "serve_round_us": serve_round_us, "serve_p99_us": serve_p99_us,
        "serve_sessions_per_sec": serve_rate,
        "loop_round_us": loop_round_us, "loop_p99_us": loop_p99_us,
        "loop_sessions_per_sec": loop_rate, "speedup": speedup,
    }]
    import jax
    out = pathlib.Path("BENCH_serving.smoke.json" if smoke
                       else "BENCH_serving.json")
    out.write_text(json.dumps(
        {"backend": jax.default_backend(), "suite": "serving",
         "entries": entries}, indent=2) + "\n")
    emit("serving_json_written", 0.0, str(out))
    baseline_path = pathlib.Path("BENCH_serving.json")
    if smoke and baseline_path.exists():
        base = json.loads(baseline_path.read_text())["entries"][0]
        pathlib.Path("BENCH_serving.compare.json").write_text(json.dumps(
            {"suite": "serving", "baseline": base, "new": entries[0],
             "note": "smoke shapes differ from the checked-in full sweep; "
                     "ratios are trajectory signal, not a gate",
             "speedup_ratio": entries[0]["speedup"] / max(
                 base["speedup"], 1e-9)}, indent=2) + "\n")
        emit("serving_compare_written", 0.0, "BENCH_serving.compare.json")

    if speedup < target:
        # a real gate, not a CSV line someone has to read: the harness
        # turns this into a nonzero exit
        raise RuntimeError(
            f"serving headline speedup {speedup:.1f}x is below the "
            f">={target:.0f}x target (engine {engine}, S={n_sessions})")
