"""Level-wise miner throughput: device-resident loop vs per-level overheads.

Times full multi-level `mine_arrays` runs (index built once, one host sync
per level) across stream sizes and engines, plus the per-level breakdown on
the largest stream. Complements bench_counting's single-call sweep: this is
the end-to-end production path the miner serves.
"""
from __future__ import annotations

import numpy as np

from repro.core import MinerConfig, mine_arrays
from repro.core.events import EventStream

from .common import emit, time_fn

STREAM_SIZES = (1024, 4096)
ENGINES = ("dense", "dense_pallas")
N_TYPES = 12


def _stream(n_events: int) -> EventStream:
    rng = np.random.default_rng(n_events + 1)
    times = np.cumsum(rng.exponential(0.25, n_events)).astype(np.float32)
    types = rng.integers(0, N_TYPES, n_events).astype(np.int32)
    return EventStream(types, times, N_TYPES)


def run() -> None:
    for n_events in STREAM_SIZES:
        stream = _stream(n_events)
        # threshold scaled so levels 2-3 keep a meaningful survivor set
        thr = max(4, n_events // 40)
        for engine in ENGINES:
            cfg = MinerConfig(t_low=0.0, t_high=1.5, threshold=thr,
                              max_level=3, engine=engine, max_candidates=512)
            us = time_fn(lambda cfg=cfg: mine_arrays(stream, cfg),
                         warmup=1, iters=2)
            res = mine_arrays(stream, cfg)
            survivors = {lvl: int(r.symbols.shape[0]) for lvl, r in res.items()}
            emit(f"mine_n{n_events}_{engine}", us,
                 f"levels={max(res)} survivors={survivors}")
