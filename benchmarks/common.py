"""Shared benchmark utilities: timing, dataset prep, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        # staticcheck: disable=REPRO004 -- benchmark timer: the sync IS the
        # measurement boundary, not a mining-loop host round-trip
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        # staticcheck: disable=REPRO004 -- benchmark timer sync (see above)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
