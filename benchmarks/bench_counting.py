"""Paper Figs 9 & 10: counting time vs dataset size, method comparison.

Fig 9: MapConcat vs the serial-FSM reference vs the best redesigned engine,
counting a batch of episodes over datasets 1-8 (time-scaled; relative
curves match the paper).
Fig 10: single-episode counting, serial FSM vs the redesigned algorithm.

Also runs the engine head-to-head sweep (dense vs dense_pallas vs
count_scan_write across episode lengths and stream sizes) and persists it
to ``BENCH_counting.json`` so successive PRs accumulate a perf trajectory
for the production counting path.

On this CPU container the "GPU" engines run as XLA:CPU programs (the
Pallas engine in interpret mode); the quantity of interest is the
*relative* scaling across dataset sizes and methods — the shape of the
paper's curves — plus the absolute numbers on real TPU hardware via the
same harness.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.core import (count_batch, count_mapconcat, count_fsm_numpy,
                        count_nonoverlapped, serial)
from repro.core.episodes import episode_batch
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset

from .common import emit, time_fn

SCALE = 0.01          # time-scale of the paper's datasets (CPU budget)
DATASETS = (4, 5, 6, 7, 8)   # larger sets dominate runtime; keep the sweep

# engine head-to-head sweep (BENCH_counting.json)
SWEEP_ENGINES = ("dense", "dense_pallas", "count_scan_write")
SWEEP_EPISODE_LENGTHS = (3, 4, 5)
SWEEP_STREAM_SIZES = (1024, 4096)
SWEEP_BATCH = 8
JSON_PATH = pathlib.Path("BENCH_counting.json")


def _sweep_stream(n_events: int, n_types: int = 8):
    rng = np.random.default_rng(n_events)
    times = np.cumsum(rng.exponential(0.5, n_events)).astype(np.float32)
    types = rng.integers(0, n_types, n_events).astype(np.int32)
    return types, times, n_types


def run_engine_sweep() -> None:
    """Engines head-to-head; emits CSV lines + BENCH_counting.json."""
    entries = []
    for n_events in SWEEP_STREAM_SIZES:
        types, times, n_types = _sweep_stream(n_events)
        for ep_len in SWEEP_EPISODE_LENGTHS:
            rng = np.random.default_rng(ep_len)
            eps = [serial(rng.integers(0, n_types, ep_len).tolist(), 0.1, 2.0)
                   for _ in range(SWEEP_BATCH)]
            sym, lo, hi = episode_batch(eps)
            for engine in SWEEP_ENGINES:
                kw = dict(n_types=n_types, cap=n_events, engine=engine)
                if engine == "count_scan_write":
                    kw.update(cap_occ=4 * n_events, max_window=64)
                us = time_fn(
                    lambda kw=kw: count_batch(types, times, sym, lo, hi, **kw),
                    warmup=1, iters=2)
                name = f"sweep_n{n_events}_len{ep_len}_{engine}"
                emit(name, us, f"batch={SWEEP_BATCH}")
                entries.append({
                    "engine": engine,
                    "episode_len": ep_len,
                    "n_events": n_events,
                    "batch": SWEEP_BATCH,
                    "us_per_call": round(us, 1),
                })
    JSON_PATH.write_text(json.dumps(
        {"backend": jax.default_backend(), "suite": "counting_engine_sweep",
         "entries": entries}, indent=2) + "\n")
    emit("sweep_json_written", 0.0, str(JSON_PATH))


def run() -> None:
    run_engine_sweep()
    cfg = NetworkConfig()
    eps = embedded_episodes(cfg)
    # 30-episode batch (paper counts 30 episodes): sub-episodes of embedded
    cands = []
    for e in eps:
        for ln in (3, 4, 5):
            for off in range(0, e.n - ln, 2):
                cands.append(e.subepisode(off, off + ln))
    # group by length for batching; use length 4 group (paper counts equal sets)
    group = [e for e in cands if e.n == 4][:30]
    sym, lo, hi = episode_batch(group)

    for idx in DATASETS:
        stream = paper_dataset(idx, scale=SCALE)
        n = stream.n_events
        cap = int(n)

        # CPU serial FSM baseline (paper's CPU implementation)
        import time as _t
        t0 = _t.perf_counter()
        for e in group[:3]:
            count_fsm_numpy(stream.types, stream.times, e)
        fsm_us = (_t.perf_counter() - t0) / 3 * len(group) * 1e6

        # redesigned engine (dense) — 30-episode batch
        us_dense = time_fn(
            lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                n_types=stream.n_types, cap=cap,
                                engine="dense"))
        # redesigned engine (paper-faithful CountScanWrite)
        us_csw = time_fn(
            lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                n_types=stream.n_types, cap=cap,
                                engine="count_scan_write",
                                cap_occ=4 * cap, max_window=32))
        # MapConcat baseline (single episode x30 scaled)
        us_mc1 = time_fn(lambda: count_mapconcat(stream, group[0],
                                                 n_segments=8, ring=16,
                                                 occ_per_segment=max(64, n // 4)))
        emit(f"fig9_ds{idx}_fsm_cpu_30ep", fsm_us, f"n_events={n}")
        emit(f"fig9_ds{idx}_mapconcat_30ep", us_mc1 * len(group), f"n_events={n}")
        emit(f"fig9_ds{idx}_redesigned_csw_30ep", us_csw, f"n_events={n}")
        emit(f"fig9_ds{idx}_redesigned_dense_30ep", us_dense, f"n_events={n}")

        # Fig 10: single episode
        one_sym, one_lo, one_hi = episode_batch(group[:1])
        us_one = time_fn(
            lambda: count_batch(stream.types, stream.times, one_sym, one_lo,
                                one_hi, n_types=stream.n_types, cap=cap,
                                engine="dense"))
        emit(f"fig10_ds{idx}_fsm_cpu_1ep", fsm_us / len(group), f"n_events={n}")
        emit(f"fig10_ds{idx}_redesigned_1ep", us_one, f"n_events={n}")
