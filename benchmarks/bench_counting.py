"""Paper Figs 9 & 10: counting time vs dataset size, method comparison.

Fig 9: MapConcat vs the serial-FSM reference vs the best redesigned engine,
counting a batch of episodes over datasets 1-8 (time-scaled; relative
curves match the paper).
Fig 10: single-episode counting, serial FSM vs the redesigned algorithm.

Also runs the engine head-to-head sweep (dense vs dense_pallas vs the
fused-batch dense_pallas_fused vs count_scan_write across episode lengths,
stream sizes, and batch sizes, plus a greedy-scheduler head-to-head) and
persists it to ``BENCH_counting.json`` so successive PRs accumulate a perf
trajectory for the production counting path
(``benchmarks/run.py --compare`` gates regressions against it).

Set ``REPRO_BENCH_SMOKE=1`` for a seconds-scale CI smoke: tiny sweep, JSON
written to BENCH_counting.smoke.json so the checked-in baseline is never
clobbered by throwaway numbers.

On this CPU container the "GPU" engines run as XLA:CPU programs (the
Pallas engine in interpret mode); the quantity of interest is the
*relative* scaling across dataset sizes and methods — the shape of the
paper's curves — plus the absolute numbers on real TPU hardware via the
same harness.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import numpy as np

from repro.core import count_batch, count_mapconcat, count_fsm_numpy, serial
from repro.core.episodes import episode_batch
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset

from .common import emit, time_fn

SCALE = 0.01          # time-scale of the paper's datasets (CPU budget)
DATASETS = (4, 5, 6, 7, 8)   # larger sets dominate runtime; keep the sweep

# engine head-to-head sweep (BENCH_counting.json)
SWEEP_ENGINES = ("dense", "dense_pallas", "dense_pallas_fused",
                 "count_scan_write")
SWEEP_EPISODE_LENGTHS = (3, 4, 5)
SWEEP_STREAM_SIZES = (1024, 4096)
SWEEP_BATCHES = (8, 32)
CSW_MAX_BATCH = 8     # count_scan_write is seconds/call at 4096; cap its sweep
# scheduler head-to-head: the host-greedy reference engine AND the fused
# single-launch engine, so every (cell, scheduler) pair has a fused entry
# for the --compare fused-min-time gate
SCHEDULER_ENGINES = ("dense", "dense_pallas_fused")
JSON_PATH = pathlib.Path("BENCH_counting.json")
SMOKE_JSON_PATH = pathlib.Path("BENCH_counting.smoke.json")


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _sweep_stream(n_events: int, n_types: int = 8):
    rng = np.random.default_rng(n_events)
    times = np.cumsum(rng.exponential(0.5, n_events)).astype(np.float32)
    types = rng.integers(0, n_types, n_events).astype(np.int32)
    return types, times, n_types


def run_engine_sweep(json_path: pathlib.Path | None = None) -> list:
    """Engines head-to-head; emits CSV lines + BENCH_counting.json.

    Every entry carries a ``scheduler`` key ("scan" = paper Algorithm 1 as
    lax.scan, "parallel" = greedy_parallel binary lifting); the scheduler
    head-to-head runs both on every SCHEDULER_ENGINES entry, everything
    else on "scan".

    ``json_path`` overrides the output file — the --compare gate passes a
    sidecar so it never clobbers the checked-in baseline it gates against.
    """
    smoke = _smoke()
    stream_sizes = (256,) if smoke else SWEEP_STREAM_SIZES
    episode_lengths = (3,) if smoke else SWEEP_EPISODE_LENGTHS
    batches = (4,) if smoke else SWEEP_BATCHES
    warmup, iters = (1, 1) if smoke else (1, 3)  # median of 3 resists outliers
    entries = []
    for n_events in stream_sizes:
        types, times, n_types = _sweep_stream(n_events)
        for ep_len in episode_lengths:
            rng = np.random.default_rng(ep_len)
            for batch in batches:
                eps = [serial(rng.integers(0, n_types, ep_len).tolist(),
                              0.1, 2.0)
                       for _ in range(batch)]
                sym, lo, hi = episode_batch(eps)
                runs = [(engine, False) for engine in SWEEP_ENGINES
                        if not (engine == "count_scan_write"
                                and batch > CSW_MAX_BATCH)]
                runs.extend((engine, True) for engine in SCHEDULER_ENGINES)
                for engine, par in runs:
                    kw = dict(n_types=n_types, cap=n_events, engine=engine,
                              parallel_schedule=par)
                    if engine == "count_scan_write":
                        kw.update(cap_occ=4 * n_events, max_window=64)
                    us = time_fn(
                        lambda kw=kw: count_batch(types, times, sym, lo, hi,
                                                  **kw),
                        warmup=warmup, iters=iters)
                    sched = "parallel" if par else "scan"
                    name = f"sweep_n{n_events}_len{ep_len}_b{batch}_{engine}"
                    if par:
                        name += "_parsched"
                    emit(name, us, f"batch={batch}")
                    entries.append({
                        "engine": engine,
                        "scheduler": sched,
                        "episode_len": ep_len,
                        "n_events": n_events,
                        "batch": batch,
                        "us_per_call": round(us, 1),
                    })
    path = json_path or (SMOKE_JSON_PATH if smoke else JSON_PATH)
    path.write_text(json.dumps(
        {"backend": jax.default_backend(), "suite": "counting_engine_sweep",
         "entries": entries}, indent=2) + "\n")
    emit("sweep_json_written", 0.0, str(path))
    return entries


def run() -> None:
    run_engine_sweep()
    if _smoke():
        return
    cfg = NetworkConfig()
    eps = embedded_episodes(cfg)
    # 30-episode batch (paper counts 30 episodes): sub-episodes of embedded
    cands = []
    for e in eps:
        for ln in (3, 4, 5):
            for off in range(0, e.n - ln, 2):
                cands.append(e.subepisode(off, off + ln))
    # group by length for batching; use length 4 group (paper counts equal sets)
    group = [e for e in cands if e.n == 4][:30]
    sym, lo, hi = episode_batch(group)

    for idx in DATASETS:
        stream = paper_dataset(idx, scale=SCALE)
        n = stream.n_events
        cap = int(n)

        # CPU serial FSM baseline (paper's CPU implementation)
        import time as _t
        t0 = _t.perf_counter()
        for e in group[:3]:
            count_fsm_numpy(stream.types, stream.times, e)
        fsm_us = (_t.perf_counter() - t0) / 3 * len(group) * 1e6

        # redesigned engine (dense) — 30-episode batch
        us_dense = time_fn(
            lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                n_types=stream.n_types, cap=cap,
                                engine="dense"))
        # redesigned engine (paper-faithful CountScanWrite)
        us_csw = time_fn(
            lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                n_types=stream.n_types, cap=cap,
                                engine="count_scan_write",
                                cap_occ=4 * cap, max_window=32))
        # MapConcat baseline (single episode x30 scaled)
        us_mc1 = time_fn(lambda: count_mapconcat(stream, group[0],
                                                 n_segments=8, ring=16,
                                                 occ_per_segment=max(64, n // 4)))
        emit(f"fig9_ds{idx}_fsm_cpu_30ep", fsm_us, f"n_events={n}")
        emit(f"fig9_ds{idx}_mapconcat_30ep", us_mc1 * len(group), f"n_events={n}")
        emit(f"fig9_ds{idx}_redesigned_csw_30ep", us_csw, f"n_events={n}")
        emit(f"fig9_ds{idx}_redesigned_dense_30ep", us_dense, f"n_events={n}")

        # Fig 10: single episode
        one_sym, one_lo, one_hi = episode_batch(group[:1])
        us_one = time_fn(
            lambda: count_batch(stream.types, stream.times, one_sym, one_lo,
                                one_hi, n_types=stream.n_types, cap=cap,
                                engine="dense"))
        emit(f"fig10_ds{idx}_fsm_cpu_1ep", fsm_us / len(group), f"n_events={n}")
        emit(f"fig10_ds{idx}_redesigned_1ep", us_one, f"n_events={n}")
