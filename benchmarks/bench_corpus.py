"""Corpus miner throughput: one device-resident level loop for B streams
vs a Python loop of per-stream ``mine_arrays`` calls.

The workload is the one the corpus miner exists for: a *ragged* corpus —
trial lengths drawn from a continuous range, the way recordings actually
arrive. The per-stream loop pays a fresh XLA compile for every
never-seen-before stream length (each length is a new static shape) plus
per-stream launch and host-sync overhead at every level; ``mine_corpus``
pads the corpus once and runs ONE fused dispatch and ONE host sync per
level regardless of B. Both paths are warmed on corpus #0, then timed on
corpus #1 (same length distribution, fresh lengths) — steady-state serving
of heterogeneous corpora, not a cold-start artifact.

The headline cell is B=32 on the fused engine, where the corpus path must
show >= 5x (the ``target`` column); the derived field carries the measured
speedup. On uniform-length corpora the loop amortizes its compiles and the
CPU interpret-mode emulation makes the head-to-head a wash — the win
claimed (and gated) here is launch/compile amortization across ragged
streams, which is also exactly the TPU serving win.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a seconds-scale CI cell.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import MinerConfig, mine_arrays, mine_corpus
from repro.core.events import EventStream

from .common import emit, time_fn

ENGINE = "dense_pallas_fused"
N_TYPES = 8
HEADLINE_BATCH = 32
SPEEDUP_TARGET = 5.0


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _ragged_corpus(seed: int, batch: int, lo: int, hi: int) -> list:
    """A corpus of ``batch`` streams with lengths drawn from [lo, hi)."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(lo, hi, batch)
    out = []
    for n in lengths:
        times = np.cumsum(rng.exponential(0.3, int(n))).astype(np.float32)
        types = rng.integers(0, N_TYPES, int(n)).astype(np.int32)
        out.append(EventStream(types, times, N_TYPES))
    return out


def _loop(streams, cfg):
    return [mine_arrays(s, cfg) for s in streams]


def run() -> None:
    smoke = _smoke()
    lo, hi = (64, 128) if smoke else (192, 384)
    batches = (4,) if smoke else (1, 8, HEADLINE_BATCH)
    cfg = MinerConfig(t_low=0.1, t_high=1.5, threshold=8 if smoke else 10,
                      max_level=3, engine=ENGINE)
    for batch in batches:
        warm = _ragged_corpus(1000 + batch, batch, lo, hi)
        fresh = _ragged_corpus(2000 + batch, batch, lo, hi)
        # warm on corpus #0, time corpus #1: the loop's per-length compiles
        # for *fresh* lengths are part of the measured cost by design —
        # that is the serving workload (`warmup=0`; corpus #0 warmed the code
        # paths both implementations share)
        _loop(warm, cfg)
        us_loop = time_fn(lambda: _loop(fresh, cfg), warmup=0, iters=1)
        mine_corpus(warm, cfg)
        us_corpus = time_fn(lambda: mine_corpus(fresh, cfg), warmup=0, iters=1)
        speedup = us_loop / max(us_corpus, 1e-9)
        emit(f"corpus_b{batch}_loop_{ENGINE}", us_loop, f"batch={batch}")
        emit(f"corpus_b{batch}_mine_corpus_{ENGINE}", us_corpus,
             f"batch={batch} speedup={speedup:.1f}x")
        if batch == HEADLINE_BATCH:
            verdict = "PASS" if speedup >= SPEEDUP_TARGET else "FAIL"
            emit("corpus_headline_speedup", us_corpus,
                 f"{speedup:.1f}x vs loop at B={batch} "
                 f"(target >={SPEEDUP_TARGET:.0f}x: {verdict})")
            if speedup < SPEEDUP_TARGET:
                # a real gate, not a CSV line someone has to read: the
                # harness turns this into a nonzero exit (measured margin
                # is ~3x the target, so noise cannot trip it)
                raise RuntimeError(
                    f"corpus headline speedup {speedup:.1f}x is below the "
                    f">={SPEEDUP_TARGET:.0f}x target at B={batch}")
