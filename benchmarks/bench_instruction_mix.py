"""Paper Table III analogue: profile-counter comparison of methods.

The CUDA Visual Profiler's instructions/branching/divergence counters have
no TPU equivalent; the XLA analogue is the trip-corrected per-opcode
instruction mix of the compiled module (analysis/hlo_costs.py), which
exposes the same story the paper tells: MapConcat's complex stitch logic
executes an order of magnitude more instructions than the redesigned
scan-based pipeline.

``single_launch_deltas`` is the asserted cell behind ISSUE 6's fused-count
claim: because the container runs Pallas in interpret mode on CPU, the
wall-clock sweep alone cannot prove a hardware win, so the single-launch
pipeline must ALSO beat the old track-then-schedule pipeline on the
instruction-mix/roofline axes — HBM bytes of the lowered module and device
dispatches (kernel launches + grid steps) per mining level both strictly
drop. The deltas are emitted, asserted, and persisted to
``BENCH_instruction_mix.json`` (smoke: a ``.smoke`` sidecar).
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import numpy as np

from repro.analysis.hlo_costs import module_costs
from repro.core import count_batch, count_mapconcat, serial
from repro.core.episodes import episode_batch
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset
from repro.kernels import autotune

from .common import emit

JSON_PATH = pathlib.Path("BENCH_instruction_mix.json")
SMOKE_JSON_PATH = pathlib.Path("BENCH_instruction_mix.smoke.json")


def _lower_costs(fn, *args):
    # staticcheck: disable=REPRO003 -- this bench exists to lower/compile
    # raw fns and read their HLO cost tables, not to run them via the cache
    compiled = jax.jit(fn).lower(*args).compile()
    return module_costs(compiled.as_text())


def single_launch_deltas(n_events: int = 512, ep_len: int = 4,
                         batch: int = 8):
    """Old (track kernel + host greedy) vs new (single-launch) count path.

    Both pipelines are lowered on one identical indexed counting cell and
    costed from optimized HLO. Returns the report dict; ``run`` asserts the
    strict drops. ``launches`` counts device program regions per mining
    level: the old path dispatches the tracking kernel AND the host-side
    compaction + greedy-scan epilogue, the fused path dispatches once;
    ``grid_steps`` is the per-launch grid from the resolved tile configs
    (the roofline model's launch-overhead axis).
    """
    rng = np.random.default_rng(0)
    times = np.cumsum(rng.exponential(0.5, n_events)).astype(np.float32)
    types = rng.integers(0, 8, n_events).astype(np.int32)
    eps = [serial(rng.integers(0, 8, ep_len).tolist(), 0.1, 2.0)
           for _ in range(batch)]
    sym, lo, hi = episode_batch(eps)
    levels = ep_len - 1

    def costs_for(engine):
        return _lower_costs(
            lambda ty, tm: count_batch(ty, tm, sym, lo, hi, n_types=8,
                                       cap=n_events, engine=engine),
            types, times)

    c_old = costs_for("dense_pallas")          # track launch + host greedy
    c_new = costs_for("dense_pallas_fused")    # ONE launch, VMEM-resident

    cfg_t = autotune.resolve("track", levels, n_events, batch)
    cfg_c = autotune.resolve("count", levels, n_events, batch)
    steps_old = autotune.model_cost(
        "track", levels, n_events, batch, cfg_t)["grid_steps"]
    steps_new = autotune.model_cost(
        "count", levels, n_events, batch, cfg_c)["grid_steps"]
    return {
        "cell": {"n_events": n_events, "episode_len": ep_len,
                 "batch": batch, "levels": levels},
        "old": {"pipeline": "dense_pallas + host greedy",
                "hbm_bytes": c_old["hbm_bytes"],
                "instructions": sum(c_old["op_mix"].values()),
                "launches_per_level": 2, "grid_steps": steps_old},
        "new": {"pipeline": "dense_pallas_fused single launch",
                "hbm_bytes": c_new["hbm_bytes"],
                "instructions": sum(c_new["op_mix"].values()),
                "launches_per_level": 1, "grid_steps": steps_new},
    }


def run_single_launch_cell() -> dict:
    """Emit + assert the fused-pipeline deltas, persist the JSON report."""
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    report = single_launch_deltas(
        n_events=256 if smoke else 512, ep_len=3 if smoke else 4,
        batch=4 if smoke else 8)
    old, new = report["old"], report["new"]
    for tag, c in (("old_trackpipe", old), ("new_singlelaunch", new)):
        emit(f"fused_count_{tag}", c["instructions"],
             f"hbm={c['hbm_bytes']:.3e};launches={c['launches_per_level']};"
             f"grid_steps={c['grid_steps']:.0f}")
    checks = {
        "hbm_bytes_drop": new["hbm_bytes"] < old["hbm_bytes"],
        "launches_drop": new["launches_per_level"] < old["launches_per_level"],
        "grid_steps_drop": new["grid_steps"] < old["grid_steps"],
    }
    report["checks"] = checks
    path = SMOKE_JSON_PATH if smoke else JSON_PATH
    path.write_text(json.dumps(
        {"backend": jax.default_backend(),
         "suite": "single_launch_instruction_mix", **report},
        indent=2) + "\n")
    emit("fused_count_json_written", 0.0, str(path))
    failed = [k for k, ok in checks.items() if not ok]
    assert not failed, (
        f"single-launch pipeline does not dominate the old track pipeline "
        f"on {failed}: old={old} new={new}")
    return report


def run() -> None:
    run_single_launch_cell()
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return
    stream = paper_dataset(2, scale=0.005)
    n = stream.n_events
    cap = int(n)
    ep = embedded_episodes(NetworkConfig())[0].subepisode(0, 4)
    sym, lo, hi = episode_batch([ep])

    lower_costs = _lower_costs

    c_csw = lower_costs(
        lambda ty, tm: count_batch(ty, tm, sym, lo, hi, n_types=stream.n_types,
                                   cap=cap, engine="count_scan_write",
                                   cap_occ=4 * cap, max_window=32),
        stream.types, stream.times)
    c_dense = lower_costs(
        lambda ty, tm: count_batch(ty, tm, sym, lo, hi, n_types=stream.n_types,
                                   cap=cap, engine="dense"),
        stream.types, stream.times)
    c_mc = lower_costs(
        lambda ty, tm: count_mapconcat(
            type(stream)(ty, tm, stream.n_types), ep, n_segments=8, ring=16,
            occ_per_segment=max(64, n // 4)),
        stream.types, stream.times)

    for name, c in (("mapconcat", c_mc), ("countscanwrite", c_csw),
                    ("dense", c_dense)):
        total_instr = sum(c["op_mix"].values())
        emit(f"table3_{name}_instructions", total_instr,
             f"flops={c['flops']:.3e};hbm={c['hbm_bytes']:.3e}")
        top = sorted(c["op_mix"].items(), key=lambda kv: -kv[1])[:5]
        emit(f"table3_{name}_topops", 0.0,
             ";".join(f"{k}:{int(v)}" for k, v in top))
