"""Paper Table III analogue: profile-counter comparison of methods.

The CUDA Visual Profiler's instructions/branching/divergence counters have
no TPU equivalent; the XLA analogue is the trip-corrected per-opcode
instruction mix of the compiled module (analysis/hlo_costs.py), which
exposes the same story the paper tells: MapConcat's complex stitch logic
executes an order of magnitude more instructions than the redesigned
scan-based pipeline.
"""
from __future__ import annotations

import jax

from repro.analysis.hlo_costs import module_costs
from repro.core import count_batch, count_mapconcat
from repro.core.episodes import episode_batch
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset

from .common import emit


def run() -> None:
    stream = paper_dataset(2, scale=0.005)
    n = stream.n_events
    cap = int(n)
    ep = embedded_episodes(NetworkConfig())[0].subepisode(0, 4)
    sym, lo, hi = episode_batch([ep])

    def lower_costs(fn, *args):
        compiled = jax.jit(fn).lower(*args).compile()
        return module_costs(compiled.as_text())

    c_csw = lower_costs(
        lambda ty, tm: count_batch(ty, tm, sym, lo, hi, n_types=stream.n_types,
                                   cap=cap, engine="count_scan_write",
                                   cap_occ=4 * cap, max_window=32),
        stream.types, stream.times)
    c_dense = lower_costs(
        lambda ty, tm: count_batch(ty, tm, sym, lo, hi, n_types=stream.n_types,
                                   cap=cap, engine="dense"),
        stream.types, stream.times)
    c_mc = lower_costs(
        lambda ty, tm: count_mapconcat(
            type(stream)(ty, tm, stream.n_types), ep, n_segments=8, ring=16,
            occ_per_segment=max(64, n // 4)),
        stream.types, stream.times)

    for name, c in (("mapconcat", c_mc), ("countscanwrite", c_csw),
                    ("dense", c_dense)):
        total_instr = sum(c["op_mix"].values())
        emit(f"table3_{name}_instructions", total_instr,
             f"flops={c['flops']:.3e};hbm={c['hbm_bytes']:.3e}")
        top = sorted(c["op_mix"].items(), key=lambda kv: -kv[1])[:5]
        emit(f"table3_{name}_topops", 0.0,
             ";".join(f"{k}:{int(v)}" for k, v in top))
