"""Streaming miner throughput: incremental ``append`` vs full remine.

The workload is the latency loop the streaming miner exists for: a long
recording already absorbed, then a sweep of small appended chunks, with the
full-stream mining result needed after every chunk (the live-analysis loop
of the paper's neuroscience pitch). The baseline pays a cold
``mine_arrays`` of the whole concatenated stream per chunk — tracking work
O(stream) per level — while ``StreamingMiner.append`` pays the incremental
index scatter plus a tail-delta recount bounded by the span suffix,
O(chunk + span) per level regardless of history length.

The baseline gets its best case: ``cfg.cap`` is pinned to the final stream
length so the cold counting path compiles ONCE instead of once per append
(only the O(n) index rebuild still re-traces per fresh length — inherent
to remining a growing stream), and both paths are warmed on the first
appends before timing. The headline cell (``dense`` engine — the fastest
single-stream engine on this backend, so the comparison is against the
strongest baseline) must show >= 5x and the harness enforces it: a
shortfall raises, it does not hide in a CSV column. Cells below target in
the wider sweep are reported honestly in the derived field.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a seconds-scale CI cell.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import EventStream, MinerConfig, StreamingMiner, mine_arrays

from .common import emit

N_TYPES = 8
SPEEDUP_TARGET = 5.0
HEADLINE_ENGINE = "dense"


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _stream(seed: int, n: int):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(0.25, n)).astype(np.float32)
    types = rng.integers(0, N_TYPES, n).astype(np.int32)
    return types, times


def _time_appends(miner: StreamingMiner, chunks) -> float:
    t0 = time.perf_counter()
    for ty, tm in chunks:
        miner.append(ty, tm)
    return (time.perf_counter() - t0) * 1e6


def _time_remine(types, times, boundaries, cfg) -> float:
    t0 = time.perf_counter()
    for end in boundaries:
        mine_arrays(EventStream(types[:end], times[:end], N_TYPES), cfg)
    return (time.perf_counter() - t0) * 1e6


def run() -> None:
    smoke = _smoke()
    base_n = 512 if smoke else 8192
    chunk = 32 if smoke else 64
    n_appends = 4 if smoke else 16
    warm_appends = 2 if smoke else 4
    engines = (HEADLINE_ENGINE,) if smoke else (HEADLINE_ENGINE, "dense_pallas_fused")
    total = base_n + (warm_appends + n_appends) * chunk
    types, times = _stream(0, total)

    for engine in engines:
        # cap pinned to the final length: the remine baseline's counting
        # path compiles once across the whole sweep (its best case)
        cfg = MinerConfig(t_low=0.05, t_high=1.0,
                          threshold=max(8, base_n // 64), max_level=3,
                          engine=engine, cap=total)
        miner = StreamingMiner(N_TYPES, cfg)
        miner.append(types[:base_n], times[:base_n])
        bounds = [base_n + (i + 1) * chunk for i in range(warm_appends + n_appends)]
        chunks = [(types[b - chunk:b], times[b - chunk:b]) for b in bounds]
        # warm both paths on the first appends (recurring steady-state shapes)
        _time_appends(miner, chunks[:warm_appends])
        _time_remine(types, times, bounds[:warm_appends], cfg)
        us_stream = _time_appends(miner, chunks[warm_appends:]) / n_appends
        us_cold = _time_remine(types, times, bounds[warm_appends:], cfg) / n_appends
        speedup = us_cold / max(us_stream, 1e-9)
        emit(f"streaming_remine_{engine}", us_cold,
             f"n={base_n}+{chunk}/append")
        emit(f"streaming_append_{engine}", us_stream,
             f"n={base_n}+{chunk}/append speedup={speedup:.1f}x")
        if engine == HEADLINE_ENGINE:
            target = 2.0 if smoke else SPEEDUP_TARGET
            verdict = "PASS" if speedup >= target else "FAIL"
            emit("streaming_headline_speedup", us_stream,
                 f"{speedup:.1f}x vs full remine ({engine}, "
                 f"target >={target:.0f}x: {verdict})")
            if speedup < target:
                # a real gate, not a CSV line someone has to read: the
                # harness turns this into a nonzero exit
                raise RuntimeError(
                    f"streaming headline speedup {speedup:.1f}x is below "
                    f"the >={target:.0f}x target (engine {engine})")
