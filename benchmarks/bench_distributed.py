"""Beyond-paper table: multi-shard scaling of the distributed miner and the
parallel overlap scheduler (paper runs subproblem-2 sequentially; our
binary-lifting scheduler keeps the stitch log-depth at pod scale)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import count_nonoverlapped, serial, shard_stream
from repro.core.distributed import make_count_sharded_jit
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset

from .common import emit, time_fn


def run() -> None:
    n_dev = len(jax.devices())
    stream = paper_dataset(3, scale=0.02)
    ep = embedded_episodes(NetworkConfig())[0].subepisode(0, 4)
    n = stream.n_events

    us1 = time_fn(lambda: count_nonoverlapped(stream, ep, engine="dense").count)
    emit("dist_1shard_dense", us1, f"n_events={n}")

    if n_dev >= 2:
        shards = min(4, n_dev)
        mesh = jax.make_mesh(
            (shards, n_dev // shards), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        ty, tm = shard_stream(stream.types, stream.times, shards)
        fn = make_count_sharded_jit(ep, mesh, n_types=stream.n_types, halo=512)
        us = time_fn(lambda: fn(ty, tm))
        emit(f"dist_{shards}shard_dense", us, f"n_events={n}")

    # parallel vs sequential overlap scheduler on a large interval set
    for par in (False, True):
        us = time_fn(lambda: count_nonoverlapped(
            stream, ep, engine="dense", parallel_schedule=par).count)
        emit(f"dist_schedule_{'parallel' if par else 'scan'}", us, f"n_events={n}")
