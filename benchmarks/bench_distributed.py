"""Beyond-paper table: multi-shard scaling of the distributed counter and
miner, plus the parallel overlap scheduler (the paper runs subproblem-2
sequentially; our binary-lifting scheduler keeps the stitch log-depth at
pod scale).

The sharded mining sweep runs full ``mine_arrays`` with a mesh — every
level's candidate batch tracked by the fused Pallas engine *inside*
``shard_map`` with one host sync per level — across shard counts, so the
emitted cells show how the flagship kernel path scales with devices.
Set ``REPRO_BENCH_SMOKE=1`` for a seconds-scale CI cell.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.core import MinerConfig, count_nonoverlapped, mine_arrays, shard_stream
from repro.core.distributed import make_count_sharded_jit
from repro.data.spikes import NetworkConfig, embedded_episodes, paper_dataset
from repro.launch.mesh import make_mesh

from .common import emit, time_fn


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _shard_counts(n_dev: int):
    return tuple(s for s in (1, 2, 4, 8) if s <= n_dev)


def _mining_stream(n_events: int, n_types: int = 12):
    rng = np.random.default_rng(n_events + 1)
    from repro.core.events import EventStream
    times = np.cumsum(rng.exponential(0.25, n_events)).astype(np.float32)
    types = rng.integers(0, n_types, n_events).astype(np.int32)
    return EventStream(types, times, n_types)


def run_sharded_mining_sweep() -> None:
    """mine_arrays on the fused engine under shard_map vs shard count."""
    n_dev = len(jax.devices())
    n_events = 512 if _smoke() else 4096
    stream = _mining_stream(n_events)
    thr = max(4, n_events // 40)
    kw = dict(t_low=0.0, t_high=1.5, threshold=thr, max_level=3,
              engine="dense_pallas_fused", max_candidates=512)

    base_cfg = MinerConfig(**kw)
    us1 = time_fn(lambda: mine_arrays(stream, base_cfg), warmup=1, iters=2)
    emit(f"shardmine_n{n_events}_unsharded_fused", us1, f"n_events={n_events}")

    for shards in _shard_counts(n_dev):
        mesh = make_mesh((shards,), ("data",))
        # halo sized to the mining window: max_span of a level-3 candidate
        # is 2 * t_high in time; in events that is span / mean_gap — 0.25
        # here — with slack (flagged, not silent, if ever short)
        halo = min(n_events, 64 if _smoke() else 256)
        cfg = MinerConfig(**kw, mesh=mesh, n_shards=shards, halo=halo)
        us = time_fn(lambda cfg=cfg: mine_arrays(stream, cfg),
                     warmup=1, iters=2)
        # cap_view = per-device tracked window: the work each chip runs.
        # On this CPU container every "device" shares the same cores, so
        # wall-clock cannot improve with shard count — the 1/shards fall of
        # cap_view is the scaling signal; wall-clock scaling comes from the
        # same harness on real multi-chip TPUs.
        n_local = -(-n_events // shards)
        cap_view = n_local + min(halo, (shards - 1) * n_local)
        emit(f"shardmine_n{n_events}_{shards}shard_fused", us,
             f"n_events={n_events} halo={halo} cap_view={cap_view}")


def run() -> None:
    n_dev = len(jax.devices())
    run_sharded_mining_sweep()
    if _smoke():
        return

    stream = paper_dataset(3, scale=0.02)
    ep = embedded_episodes(NetworkConfig())[0].subepisode(0, 4)
    n = stream.n_events

    us1 = time_fn(lambda: count_nonoverlapped(stream, ep, engine="dense").count)
    emit("dist_1shard_dense", us1, f"n_events={n}")

    if n_dev >= 2:
        shards = min(4, n_dev)
        mesh = make_mesh((shards, n_dev // shards), ("data", "model"))
        ty, tm = shard_stream(stream.types, stream.times, shards)
        fn = make_count_sharded_jit(ep, mesh, n_types=stream.n_types, halo=512)
        us = time_fn(lambda: fn(ty, tm))
        emit(f"dist_{shards}shard_dense", us, f"n_events={n}")

    # parallel vs sequential overlap scheduler on a large interval set
    for par in (False, True):
        us = time_fn(lambda: count_nonoverlapped(
            stream, ep, engine="dense", parallel_schedule=par).count)
        emit(f"dist_schedule_{'parallel' if par else 'scan'}", us, f"n_events={n}")
