"""Paper Fig 12: counting time vs episode frequency.

Episode frequency is controlled by injecting cascades at increasing rates
into a fixed-noise stream. The paper's key observation — runtime follows
the *overlapped* superset size, not the final non-overlapped count, with a
bump where overlap explodes — reproduces in the faithful engines; the
beyond-paper dense engine stays flat by construction (dominance pruning),
which is the headline beyond-paper result for this figure.
"""
from __future__ import annotations

import numpy as np

from repro.core import count_batch, count_nonoverlapped
from repro.core.episodes import episode_batch, serial
from repro.core.events import EventStream

from .common import emit, time_fn


def stream_with_rate(inject_hz: float, duration: float = 60.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_types = 8
    noise_n = rng.poisson(20.0 * n_types * duration)
    t = [rng.uniform(0, duration, noise_n)]
    e = [rng.integers(0, n_types, noise_n)]
    ep = serial([0, 1, 2], 0.0, 0.02)
    n_inj = int(inject_hz * duration)
    starts = rng.uniform(0, duration, n_inj)
    for t0 in starts:
        tt = t0
        for s in ep.symbols:
            t.append([tt]); e.append([s])
            tt += rng.uniform(0.002, 0.018)
    times = np.concatenate([np.asarray(x, np.float64).ravel() for x in t])
    types = np.concatenate([np.asarray(x, np.int64).ravel() for x in e])
    order = np.argsort(times, kind="stable")
    return EventStream(types[order].astype(np.int32),
                       times[order].astype(np.float32), n_types), ep


def run() -> None:
    for hz in (1, 5, 20, 80, 320):
        stream, ep = stream_with_rate(hz)
        n = stream.n_events
        cap = int(n)
        sym, lo, hi = episode_batch([ep])
        res = count_nonoverlapped(stream, ep, engine="dense")
        freq = int(res.count)
        superset = int(res.n_superset)
        for engine in ("count_scan_write", "atomic_sort", "dense"):
            kw = {} if engine == "dense" else dict(cap_occ=16 * cap, max_window=64)
            us = time_fn(
                lambda: count_batch(stream.types, stream.times, sym, lo, hi,
                                    n_types=stream.n_types, cap=cap,
                                    engine=engine, **kw))
            emit(f"fig12_rate{hz}_{engine}", us,
                 f"freq={freq};superset={superset};n_events={n}")
