"""Quick host-side validation of the core mining engine vs the numpy oracle."""
import numpy as np

from repro.core import (
    EventStream, serial, count_nonoverlapped, count_fsm_numpy,
    count_fsm_scan, count_mapconcat, count_all_occurrences_numpy, greedy_numpy,
    ENGINES,
)


def random_stream(rng, n=400, n_types=6, rate=1.0):
    times = np.cumsum(rng.exponential(1.0 / rate, size=n)).astype(np.float32)
    types = rng.integers(0, n_types, size=n).astype(np.int32)
    return EventStream(types, times, n_types)


def main():
    rng = np.random.default_rng(0)
    n_fail = 0
    for trial in range(30):
        n_types = int(rng.integers(2, 6))
        s = random_stream(rng, n=int(rng.integers(50, 400)), n_types=n_types,
                          rate=float(rng.uniform(0.5, 3.0)))
        n = int(rng.integers(1, 5))
        ep = serial(rng.integers(0, n_types, size=n).tolist(),
                    float(rng.uniform(0, 1)), float(rng.uniform(1.5, 6)))
        want = count_fsm_numpy(s.types, s.times, ep)
        # oracle #2: exact superset + greedy
        st, en = count_all_occurrences_numpy(s.types, s.times, ep)
        want2 = greedy_numpy(st, en)
        if want != want2:
            print(
                f"[{trial}] ORACLE DISAGREEMENT fsm={want} "
                f"superset-greedy={want2} ep={ep}")
            n_fail += 1
            continue
        for engine in ENGINES:
            got = count_nonoverlapped(
                s, ep, engine=engine, cap_occ=24 * s.n_events, max_window=128)
            if int(got.count) != want or bool(got.overflow):
                print(f"[{trial}] engine={engine} got={int(got.count)} want={want} "
                      f"overflow={bool(got.overflow)} ep={ep}")
                n_fail += 1
        # parallel scheduler
        got_p = count_nonoverlapped(s, ep, engine="dense", parallel_schedule=True)
        if int(got_p.count) != want:
            print(f"[{trial}] parallel-schedule got={int(got_p.count)} want={want}")
            n_fail += 1
        # scan FSM
        got_fsm = count_fsm_scan(s.types, s.times, ep, ring=16)[0]
        if int(got_fsm) != want:
            print(f"[{trial}] fsm-scan got={int(got_fsm)} want={want} ep={ep}")
            n_fail += 1
        # mapconcat
        got_mc = count_mapconcat(s, ep, n_segments=4, ring=48,
                                 occ_per_segment=max(64, s.n_events))
        if int(got_mc) != want:
            print(f"[{trial}] mapconcat got={int(got_mc)} want={want} ep={ep}")
            n_fail += 1
    print("FAILURES:", n_fail)


if __name__ == "__main__":
    main()
