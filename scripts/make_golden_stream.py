"""Generate tests/data/golden_stream.npz — the end-to-end mining fixture.

A small simulated spike train (data/spikes.py network model, two planted
cascades) plus the exact per-level frequent-episode sets the miner must
recover. The fixture is CHECKED IN; regenerating it (after an intentional
miner-semantics change) is:

    PYTHONPATH=src python scripts/make_golden_stream.py

The stored levels are produced by the reference ``dense`` engine and
sanity-checked here: the planted cascades' prefixes must appear at the
deepest level, and every stored count must be reproduced by the numpy FSM
oracle — so the fixture can never encode an engine bug as truth.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import MinerConfig, count_fsm_numpy, mine_arrays  # noqa: E402
from repro.core.episodes import episodes_from_rows  # noqa: E402
from repro.data.spikes import NetworkConfig, embedded_episodes, simulate  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                   "golden_stream.npz")

NET = NetworkConfig(n_neurons=12, episode_len=5, n_embedded=2,
                    base_rate=2.0, trigger_hz=3.0, seed=7)
DURATION_S = 4.0
MINER = dict(t_low=0.0, t_high=2 * NET.delay_high, threshold=7, max_level=4,
             max_candidates=2048)


def main() -> None:
    stream = simulate(NET, DURATION_S)
    planted = embedded_episodes(NET)
    cfg = MinerConfig(**MINER, engine="dense")
    res = mine_arrays(stream, cfg)

    # sanity 1: the planted cascades' prefixes are recovered at max level
    deepest = max(res)
    assert deepest >= 3, f"fixture too shallow: deepest level {deepest}"
    found = {tuple(int(x) for x in row) for row in res[deepest].symbols}
    hits = [p for p in planted if p.symbols[:deepest] in found]
    assert hits, f"no planted episode recovered at level {deepest}"

    # sanity 2: every stored count reproduces on the serial FSM oracle
    types = np.asarray(stream.types)
    times = np.asarray(stream.times)
    for lvl, la in res.items():
        if lvl == 1:
            binc = np.bincount(types, minlength=stream.n_types)
            np.testing.assert_array_equal(la.counts, binc[la.symbols[:, 0]])
            continue
        for row, count in zip(
                episodes_from_rows(la.symbols, cfg.t_low, cfg.t_high),
                la.counts):
            assert count_fsm_numpy(types, times, row) == int(count), row

    payload = {
        "types": types.astype(np.int32),
        "times": times.astype(np.float32),
        "n_types": np.int32(stream.n_types),
        "t_low": np.float32(cfg.t_low),
        "t_high": np.float32(cfg.t_high),
        "threshold": np.int32(cfg.threshold),
        "max_level": np.int32(cfg.max_level),
        "max_candidates": np.int32(cfg.max_candidates),
        "levels": np.asarray(sorted(res), np.int32),
        "planted_symbols": np.asarray(
            [p.symbols for p in planted], np.int32),
    }
    for lvl, la in res.items():
        payload[f"level{lvl}_symbols"] = la.symbols.astype(np.int32)
        payload[f"level{lvl}_counts"] = np.asarray(la.counts, np.int32)
        payload[f"level{lvl}_n_candidates"] = np.int32(la.n_candidates)

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    np.savez(OUT, **payload)
    sizes = {lvl: int(res[lvl].symbols.shape[0]) for lvl in sorted(res)}
    print(f"wrote {os.path.relpath(OUT)}: {stream.n_events} events, "
          f"levels {sizes}, planted hit: {hits[0].symbols[:deepest]}")


if __name__ == "__main__":
    main()
