#!/usr/bin/env python
"""Run the repro staticcheck (DESIGN.md §13).

Modes:
  --all            lint every file + jaxpr-check the default plan matrix
                   (the blocking CI job; this is the default mode)
  --changed-only   lint only files changed vs HEAD, skip the jaxpr layer
                   (fast local pre-commit loop)
  --full-matrix    --all with the nightly shape-swept plan matrix
  --hlo            additionally compile one representative plan and walk
                   its optimized HLO for host custom-calls
  --report PATH    write the JSON report artifact
  --list-rules     print the rule table and exit

Exit status: 0 iff there are zero unsuppressed findings.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import staticcheck  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--all", action="store_true",
                      help="full tree + default plan matrix (default)")
    mode.add_argument("--changed-only", action="store_true",
                      help="lint changed files only; skip the jaxpr layer")
    mode.add_argument("--full-matrix", action="store_true",
                      help="full tree + nightly shape-swept plan matrix")
    ap.add_argument("--hlo", action="store_true",
                    help="also compile+walk one representative plan's HLO")
    ap.add_argument("--report", type=Path, default=None,
                    help="write the JSON report artifact here")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, desc in sorted(staticcheck.RULES.items()):
            print(f"{code}  {desc}")
        return 0

    root = staticcheck.runner.repo_root()
    if args.changed_only:
        files = staticcheck.changed_files(root)
        report = staticcheck.run(root=root, files=files, jaxpr=False)
    else:
        matrix = "full" if args.full_matrix else "default"
        report = staticcheck.run(root=root, matrix=matrix, hlo=args.hlo)

    print(report["text"])
    print(f"staticcheck: {report['files_checked']} files, "
          f"{report['plans_checked']} plans (matrix={report['matrix']})")
    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(staticcheck.report_json(report))
        print(f"staticcheck: report written to {args.report}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
