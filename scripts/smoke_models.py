"""One train step + decode step per reduced arch on CPU; shape/NaN asserts."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.models import Model


def batch_for(cfg, b=2, s=32, key=None):
    key = key or jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    if cfg.frontend == "vision":
        s_text = s - cfg.n_patches
        return {
            "tokens": jax.random.randint(ks[0], (b, s_text), 0, cfg.vocab),
            "patches": jax.random.normal(ks[1], (b, cfg.n_patches, cfg.d_patch)),
            "targets": jax.random.randint(ks[2], (b, s_text), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "targets": jax.random.randint(ks[2], (b, s), 0, cfg.vocab),
    }


def main():
    fails = 0
    for name, full_cfg in sorted(REGISTRY.items()):
        t0 = time.time()
        cfg = reduced(full_cfg)
        m = Model(cfg, remat="none")
        params = m.init(jax.random.PRNGKey(1))
        batch = batch_for(cfg)
        try:
            (loss, metrics), grads = jax.jit(
                jax.value_and_grad(m.loss, has_aux=True))(params, batch)
            loss = float(loss)
            gflat = jax.tree.leaves(grads)
            gnorm = float(jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32))) for g in gflat)))
            assert np.isfinite(loss), f"loss NaN {loss}"
            assert np.isfinite(gnorm), "grad NaN"
            # decode
            cache = m.init_cache(2, 64)
            toks = jnp.zeros((2,), jnp.int32)
            pos = jnp.zeros((2,), jnp.int32)
            logits, cache = jax.jit(m.decode_step)(params, cache, toks, pos)
            assert logits.shape == (2, cfg.vocab), logits.shape
            assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), "decode NaN"
            print(f"{name:24s} loss={loss:8.4f} gnorm={gnorm:9.3f} "
                  f"ln(V)={np.log(cfg.vocab):6.3f} {time.time()-t0:5.1f}s OK")
        except Exception as e:
            fails += 1
            print(f"{name:24s} FAIL: {type(e).__name__}: {e}")
    print("FAILURES:", fails)


if __name__ == "__main__":
    main()
