"""Validate shard_map sharded counting vs oracle (run with fake devices)."""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.core import serial, shard_stream, count_fsm_numpy
from repro.core.distributed import make_count_sharded_jit

rng = np.random.default_rng(1)
fails = 0
mesh = jax.make_mesh((4, 2), ("data", "model"))
for trial in range(4):
    n = 480
    n_types = 5
    times = np.cumsum(rng.exponential(0.5, size=n)).astype(np.float32)
    types = rng.integers(0, n_types, size=n).astype(np.int32)
    nsym = int(rng.integers(2, 5))
    ep = serial(rng.integers(0, n_types, size=nsym).tolist(), 0.1, 3.0)
    want = count_fsm_numpy(types, times, ep)
    ty_s, tm_s = shard_stream(types, times, 4)
    t0 = time.time()
    fn = make_count_sharded_jit(ep, mesh, n_types=n_types, halo=120)
    got, short, overflow = fn(ty_s, tm_s)
    ok = int(got) == want and not bool(short) and not bool(overflow)
    print(f"[{trial}] got={int(got)} want={want} short={bool(short)} "
          f"{time.time()-t0:.1f}s")
    if not ok:
        fails += 1
print("FAILURES:", fails)
