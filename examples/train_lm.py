"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on CPU with the full production stack — sharding rules,
AdamW, async checkpointing, crash-resume, straggler telemetry feeding the
paper's episode miner.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticCorpus
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import StragglerMonitor, resilient_train_loop
from repro.distributed.sharding import MeshRules
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.optim import AdamW
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # ~100M params: qwen3-0.6b config narrowed (vocab is most of 0.6B)
    cfg = dataclasses.replace(
        get_config("qwen3-0.6b"), name="qwen3-100m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2304, vocab=8192)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    rules = MeshRules(mesh)
    model = Model(cfg, constrain=rules.constrain, remat="none", mesh=mesh)
    opt = AdamW(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), mesh {mesh.shape}")

    opt_state = opt.init(params)
    data = SyntheticCorpus(DataConfig(
        seq_len=args.seq_len, global_batch=args.batch, vocab=cfg.vocab,
        kind="markov"))
    step_fn_raw = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step_fn_raw(params, opt_state, batch)
        return (params, opt_state), metrics

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    monitor = StragglerMonitor()
    losses = []

    def on_metrics(step, m):
        losses.append(m["loss"])
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {m['loss']:.4f} "
                  f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}", flush=True)

    t0 = time.time()
    (params, opt_state), start, hist = resilient_train_loop(
        step_fn=step_fn, init_state=(params, opt_state),
        batch_iter=data.batches(), checkpointer=ckpt, n_steps=args.steps,
        ckpt_every=100, monitor=monitor, on_metrics=on_metrics,
        resume=args.resume)
    dt = time.time() - t0
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\ntrained steps {start}..{args.steps} in {dt:.0f}s "
          f"({dt/max(1,len(hist)):.2f}s/step)")
    print(f"loss {first:.3f} -> {last:.3f}  (ckpts: {ckpt.list_steps()})")
    assert last < first - 0.3, "loss should decrease substantially"
    print("OK: loss decreased; checkpoint/resume available via --resume")


if __name__ == "__main__":
    main()
