"""Paper end-to-end reproduction: simulate a 64-neuron MEA culture
(inhomogeneous Poisson network, 4 embedded 9-node episodes — paper §V-A),
then recover the embedded cascades by level-wise frequent episode mining.

    PYTHONPATH=src python examples/neuroscience_mining.py [--duration 20]
"""
import argparse
import time


from repro.core import MinerConfig, mine
from repro.data.spikes import (NetworkConfig, embedded_episodes,
                               noise_pair_estimate, simulate)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=20.0,
                    help="simulated seconds (paper datasets: 20..4000)")
    ap.add_argument("--max-level", type=int, default=5)
    args = ap.parse_args()

    net = NetworkConfig()
    truth = embedded_episodes(net)
    print(f"simulating {net.n_neurons} neurons for {args.duration}s "
          f"({net.base_rate} Hz noise, {len(truth)} embedded 9-node episodes)")
    stream = simulate(net, args.duration)
    print(f"-> {stream.n_events} spikes")

    # threshold: 1.4x the expected chance count of a noise pair, so level-2
    # keeps cascade pairs (injection + noise) and drops coincidences
    noise_est = noise_pair_estimate(net, args.duration)
    # deeper levels: cascade counts decay ~conn_strength per level while the
    # combinatorial noise floor collapses, so the threshold steps down
    deep_thr = max(5, int(0.35 * net.trigger_hz * args.duration))
    cfg = MinerConfig(
        t_low=0.0, t_high=2 * net.delay_high,
        threshold=deep_thr,
        level_thresholds={2: int(1.4 * noise_est)},
        max_level=args.max_level, engine="dense",
        max_candidates=net.n_neurons ** 2)
    t0 = time.time()
    results = mine(stream, cfg)
    dt = time.time() - t0

    truth_prefixes = {ep.symbols[:lv] for ep in truth
                      for lv in range(2, args.max_level + 1)}
    print(f"mining to level {args.max_level} took {dt:.1f}s")
    found_any = 0
    for level in sorted(results):
        lr = results[level]
        if level == 1:
            print(f"level 1: {len(lr.episodes)} active neurons")
            continue
        hits = [e for e in lr.episodes if e.symbols in truth_prefixes]
        found_any += len(hits)
        print(f"level {level}: {len(lr.episodes)} frequent / "
              f"{lr.n_candidates} candidates; {len(hits)} are embedded-cascade "
              f"prefixes, e.g. {hits[0] if hits else '-'}")
    assert found_any > 0, "mining should recover embedded cascades"
    print("OK: embedded cascades recovered from simulated spike trains")


if __name__ == "__main__":
    main()
