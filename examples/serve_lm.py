"""Batched serving: decode tokens from a small model with a KV cache,
mirroring the decode_32k dry-run cell at laptop scale.

    PYTHONPATH=src python examples/serve_lm.py [--tokens 64] [--batch 4]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch), name="serve-small",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=768, vocab=4096)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    b = args.batch
    cache = model.init_cache(b, args.cache_len)
    tokens = jnp.zeros((b,), jnp.int32)
    key = jax.random.PRNGKey(42)
    out = []
    t0 = time.time()
    for pos in range(args.tokens):
        logits, cache = serve_step(params, cache,
                                   tokens, jnp.full((b,), pos, jnp.int32))
        key, sub = jax.random.split(key)
        tokens = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        out.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    seqs = jnp.stack(out, axis=1)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    print(f"decoded {args.tokens} tokens x {b} sequences in {dt:.1f}s "
          f"({b*args.tokens/dt:.1f} tok/s)")
    print("sample token ids:", seqs[0, :16].tolist())
    print("OK: batched KV-cache serving works")


if __name__ == "__main__":
    main()
