"""Quickstart: count and mine frequent episodes in an event stream.

    PYTHONPATH=src python examples/quickstart.py

Before sending a PR, run the static invariant checker (DESIGN.md §13) —
it lint-checks the tree and traces the registered plan matrix, and CI
runs it blocking:

    PYTHONPATH=src python scripts/staticcheck.py --all       # what CI runs
    PYTHONPATH=src python scripts/staticcheck.py --changed-only  # fast loop
"""
import numpy as np

from repro.core import (EventStream, MinerConfig, MiningSessionServer,
                        StreamingMiner, cache_stats, count_fsm_numpy,
                        count_nonoverlapped, mine, plans_for_miner, serial,
                        warm)


def main():
    rng = np.random.default_rng(7)

    # An event stream: 6 event types, Poisson noise + an embedded cascade
    # 0 -> 1 -> 2 with 5-15 ms delays (the paper's running example shape).
    n_types, duration = 6, 30.0
    t_noise = rng.uniform(0, duration, rng.poisson(40 * duration))
    e_noise = rng.integers(0, n_types, t_noise.size)
    t_inj, e_inj = [], []
    for t0 in rng.uniform(0, duration, 60):
        t = t0
        for sym in (0, 1, 2):
            t_inj.append(t)
            e_inj.append(sym)
            t += rng.uniform(0.005, 0.015)
    times = np.concatenate([t_noise, t_inj])
    types = np.concatenate([e_noise, e_inj]).astype(np.int32)
    order = np.argsort(times)
    stream = EventStream(types[order], times[order].astype(np.float32), n_types)

    # 1) Count one constrained episode, redesigned (paper) engine vs oracle
    ep = serial([0, 1, 2], 0.004, 0.016)
    res = count_nonoverlapped(stream, ep, engine="count_scan_write",
                              cap_occ=4 * stream.n_events)
    oracle = count_fsm_numpy(stream.types, stream.times, ep)
    print(f"episode {ep}: count={int(res.count)} (oracle {oracle}), "
          f"superset tracked={int(res.n_superset)}")

    # 2) Level-wise mining: discovers the embedded cascade automatically.
    # Preload the executable cache first (DESIGN.md §11): every
    # (level, batch-class) bucket this config can dispatch compiles here,
    # so the mining loop itself never stops to compile.
    cfg = MinerConfig(t_low=0.004, t_high=0.016, threshold=30, max_level=3)
    warmed = warm(plans_for_miner(cfg, n_types=n_types,
                                  n_events=stream.n_events))
    print(f"plan cache warmed: {warmed['compiled']} executable(s) compiled "
          f"ahead of mining")
    results = mine(stream, cfg)
    for level, lr in results.items():
        shown = ", ".join(f"{e}(n={c})" for e, c in
                          zip(lr.episodes[:4], lr.counts[:4]))
        print(f"level {level}: {len(lr.episodes)} frequent "
              f"of {lr.n_candidates} candidates: {shown}")
    top3 = results.get(3)
    assert top3 and any(e.symbols == (0, 1, 2) for e in top3.episodes), \
        "embedded cascade should be discovered"
    stats = cache_stats()
    print(f"plan cache: {stats['hits']} hit(s), {stats['misses']} miss(es) "
          "after warm (0 misses = every level ran a preloaded executable)")
    print("OK: embedded cascade 0->1->2 discovered")

    # 3) Multi-tenant serving (DESIGN.md §12): many live sessions, each an
    # incrementally-growing stream, mined in ONE batched pool pass per
    # flush. Each session's result is bit-for-bit a standalone
    # StreamingMiner fed the same chunks.
    srv = MiningSessionServer(n_types, cfg, max_sessions=4, initial_cap=256)
    srv.warm()                     # serving startup: preload every bucket
    half = stream.n_events // 2
    sessions = [srv.create_session() for _ in range(3)]
    for sid in sessions:           # first chunk for every session...
        srv.append(sid, stream.types[:half], stream.times[:half])
    srv.flush()                    # ...absorbed in one batched level loop
    for sid in sessions:           # streams keep growing
        srv.append(sid, stream.types[half:], stream.times[half:])
    got = srv.results(sessions[0])  # reads flush all pending sessions
    solo = StreamingMiner(n_types, cfg, initial_cap=256)
    solo.append(stream.types[:half], stream.times[:half])
    ref = solo.append(stream.types[half:], stream.times[half:])
    assert all(np.array_equal(got[lv].counts, ref[lv].counts) for lv in ref)
    print(f"serving pool: {len(sessions)} sessions mined per flush, "
          "each == its standalone StreamingMiner")


if __name__ == "__main__":
    main()
