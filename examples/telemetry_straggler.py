"""The paper's technique on the framework's own control plane: mine the
training runtime's telemetry event stream for chained-slowness episodes
(the straggler signature). See DESIGN.md §4 and distributed/fault_tolerance.

Scoring runs through the multi-tenant serving pool (core/serving.py): each
host is one session in a ``MiningSessionServer``, its SLOW events stream
in live as steps complete, and ``scores()`` absorbs every host's pending
events in ONE batched pool flush — the same counts the cold per-host
``telemetry.straggler_scores`` loop produces, at a fixed number of device
dispatches regardless of host count.

    PYTHONPATH=src python examples/telemetry_straggler.py
"""
import numpy as np

from repro.core import telemetry
from repro.distributed.fault_tolerance import StragglerMonitor


def main():
    rng = np.random.default_rng(3)
    hosts = [f"host{i}" for i in range(16)]
    mon = StragglerMonitor(window=30.0, repeat=3, min_count=2)

    # Simulate 200 training steps: host7 degrades persistently after step 60
    # (e.g. thermal throttling); host12 has two isolated blips (not a
    # straggler — the non-overlapped episode count is burst-insensitive).
    wall = 0.0
    for step in range(200):
        base = rng.normal(2.0, 0.05, len(hosts)).clip(1.8, None)
        durs = dict(zip(hosts, base))
        if step > 60:
            durs["host7"] = float(base[7] * rng.uniform(1.8, 2.6))
        if step in (30, 120):
            durs["host12"] = float(base[12] * 3.0)
        wall += max(durs.values())
        mon.record_step(durs, wall)

    scores = mon.scores()
    print("straggler scores (non-overlapped chained-SLOW episode count,")
    print(f"mined via a {len(mon._sessions.server)}-session serving pool):")
    for h, c in sorted(scores.items(), key=lambda kv: -kv[1]):
        print(f"  {h:8s} {c}")
    flagged = mon.flagged()
    print("flagged:", flagged)
    assert "host7" in flagged, "persistent straggler must be flagged"
    assert "host12" not in flagged, "isolated blips must not be flagged"

    # the serving path and the cold per-host counting loop are the same
    # count (the serving differential bar, checked here on real telemetry)
    cold = telemetry.straggler_scores(
        mon.log, window=mon.window, repeat=mon.repeat)
    assert scores == cold, (scores, cold)
    print("OK: persistent straggler isolated from benign blips; "
          "serving-pool scores == cold per-host counting loop")


if __name__ == "__main__":
    main()
