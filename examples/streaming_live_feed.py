"""Live-feed mining demo: frequent episodes tracked as spikes arrive.

    PYTHONPATH=src python examples/streaming_live_feed.py

Simulates the recording loop the paper's neuroscientists sit in: a
multi-electrode stream arrives in small chunks, and after every chunk the
analysis must reflect the WHOLE recording so far. A cascade 0 -> 1 -> 2
(5-15 ms delays) is injected only in the second half of the session, so the
demo shows the miner's result *changing* mid-stream: the cascade is absent
from the early reports, then crosses the threshold and appears — the
moment a cold remine would have found it too, but at per-chunk incremental
cost (StreamingMiner recounts only the span-bounded tail and stitches onto
cached greedy state; see DESIGN.md §9).
"""
import time

import numpy as np

from repro.core import (EventStream, MinerConfig, StreamingMiner,
                        cache_stats, mine_arrays, warm)


def make_session(rng, n_types=6, duration=40.0, cascade_after=20.0):
    """Poisson background; the 0->1->2 cascade only after ``cascade_after``."""
    t_noise = rng.uniform(0, duration, rng.poisson(30 * duration))
    e_noise = rng.integers(0, n_types, t_noise.size)
    t_inj, e_inj = [], []
    for t0 in rng.uniform(cascade_after, duration, 80):
        t = t0
        for sym in (0, 1, 2):
            t_inj.append(t)
            e_inj.append(sym)
            t += rng.uniform(0.005, 0.015)
    times = np.concatenate([t_noise, t_inj]).astype(np.float32)
    types = np.concatenate([e_noise, e_inj]).astype(np.int32)
    order = np.argsort(times, kind="stable")
    return types[order], times[order]


def main():
    rng = np.random.default_rng(11)
    n_types = 6
    types, times = make_session(rng, n_types)
    cfg = MinerConfig(t_low=0.004, t_high=0.016, threshold=40, max_level=3)
    # Serving startup (DESIGN.md §11): size the index for the whole session
    # up front (no mid-session growth, hence no mid-session recompile) and
    # warm every executable the live loop can dispatch — plain per-level
    # counts, cold backfills, and tail recounts at the expected tail-view
    # widths (chunk size + event rate x constraint span bound them).
    per_type = int(np.bincount(types, minlength=n_types).max())
    miner = StreamingMiner(n_types, cfg, initial_cap=per_type)
    warmed = warm(miner.plans(tail_caps=(16, 32, 64)))
    print(f"plan cache warmed: {warmed['compiled']} executable(s) before "
          "the first chunk")

    chunk = max(1, types.size // 16)
    seen = set()
    print(f"session: {types.size} events, fed in {chunk}-event chunks")
    for start in range(0, types.size, chunk):
        ty, tm = types[start:start + chunk], times[start:start + chunk]
        t0 = time.perf_counter()
        results = miner.append(ty, tm)
        dt = (time.perf_counter() - t0) * 1e3
        top = results.get(3)
        found = ({tuple(int(x) for x in row) for row in top.symbols}
                 if top else set())
        fresh = found - seen
        line = (f"t={miner.last_time:6.2f}s  n={miner.n_events:5d}  "
                f"append={dt:6.1f}ms  3-node frequent={len(found)}")
        if fresh:
            line += "  NEW: " + ", ".join(
                "->".join(map(str, f)) for f in sorted(fresh))
        print(line)
        seen = found

    stats = cache_stats()
    print(f"plan cache after the session: {stats['hits']} hit(s), "
          f"{stats['misses']} miss(es) — every miss is one compile the "
          "warm() preload did not anticipate")
    assert (0, 1, 2) in seen, "injected cascade should be discovered"
    # the streaming state is bit-for-bit the cold answer on the full session
    cold = mine_arrays(EventStream(types, times, n_types), cfg)
    got = miner.results
    assert set(got) == set(cold)
    for lvl in cold:
        assert np.array_equal(got[lvl].symbols, cold[lvl].symbols)
        assert np.array_equal(got[lvl].counts, cold[lvl].counts)
    print("OK: cascade 0->1->2 discovered mid-session; final state matches "
          "a cold remine bit-for-bit")


if __name__ == "__main__":
    main()
